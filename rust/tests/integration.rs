//! End-to-end integration: the full public-API chain on tiny budgets, the
//! quantized serving path, and failure handling. Skips (with a notice) when
//! `make artifacts` has not run.

use std::path::PathBuf;
use std::sync::Arc;

use msfp::config::{MethodSpec, Scale};
use msfp::coordinator::{self, Backend, Request, ServeMode, ServerCfg};
use msfp::data::Corpus;
use msfp::eval::generate::SamplerKind;
use msfp::lora::hub::AllocStrategy;
use msfp::lora::Router;
use msfp::pipeline::Pipeline;
use msfp::runtime::{Denoiser, QuantState};
use msfp::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("manifest.json").exists().then_some(d)
}

fn tiny_scale() -> Scale {
    Scale {
        pretrain_steps: 20,
        traj_samples: 4,
        ft_epochs: 1,
        eval_n: 32,
        ref_n: 64,
        steps: 4,
        calib_rounds: 2,
    }
}

#[test]
fn quantize_then_serve_quantized() {
    let Some(dir) = artifacts() else {
        msfp::log_warn!("skipping: artifacts not built");
        return;
    };
    std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_integ_runs"));
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p = pl.prepare(Corpus::CifarSyn).unwrap();
    let calib = pl.calibrate(&p).unwrap();

    // MSFP 4-bit with a 1-epoch TALoRA fine-tune
    let spec = MethodSpec::ours(4, 2, 1);
    let q = pl.quantize(&p, &spec, &calib).unwrap();
    assert!(q.scheme.n_aal() > 0);
    assert!(q.scheme.unsigned_fraction_on_aals() > 0.5);
    let stats = q.ft_stats.as_ref().unwrap();
    assert!(stats.losses.iter().all(|l| l.is_finite()));

    // serve the quantized model through the coordinator
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &p.info).unwrap());
    let handle = coordinator::spawn(
        den,
        p.info.clone(),
        pl.sched.clone(),
        Arc::new(p.params.clone()),
        ServerCfg { seed: 7, ..ServerCfg::new(ServeMode::Quant(q.state)) },
    );
    let mut rxs = Vec::new();
    for i in 0..4 {
        let mut req = Request::new(0, 2, 4);
        req.seed = i;
        rxs.push(handle.submit(req).unwrap());
    }
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap_done();
        assert_eq!(resp.n, 2);
        assert!(resp.images.iter().all(|v| v.is_finite()));
    }
    let m = handle.shutdown();
    assert_eq!(m.images_done, 8);
    assert!(m.mean_batch() > 1.0, "quantized serving did not batch: {}", m.report());
    std::env::remove_var("MSFP_RUNS");
}

#[test]
fn serving_mixed_samplers_and_conditional() {
    let Some(dir) = artifacts() else { return };
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let info = pl.manifest.model("ldm8c").unwrap().clone();
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(
        msfp::model::ParamStore::load_init(&info, &dir).unwrap().flat,
    );
    let handle = coordinator::spawn(
        den,
        info,
        pl.sched.clone(),
        params,
        ServerCfg { decode_latents: true, seed: 1, ..ServerCfg::new(ServeMode::Fp) },
    );
    let mut ddim = Request::new(0, 2, 4);
    ddim.class = Some(3);
    let mut plms = Request::new(0, 1, 4);
    plms.sampler = SamplerKind::Plms;
    let mut dpm = Request::new(0, 1, 3);
    dpm.sampler = SamplerKind::DpmSolver2;
    let rx1 = handle.submit(ddim).unwrap();
    let rx2 = handle.submit(plms).unwrap();
    let rx3 = handle.submit(dpm).unwrap();
    let r1 = rx1.recv().unwrap().unwrap_done();
    let r2 = rx2.recv().unwrap().unwrap_done();
    let r3 = rx3.recv().unwrap().unwrap_done();
    // latents decoded to 32x32 pixels
    assert_eq!(r1.images.len(), 2 * 32 * 32 * 3);
    assert_eq!(r2.images.len(), 32 * 32 * 3);
    assert_eq!(r3.evals, 2 * (3 - 1)); // DPM-Solver-2: 2 evals per step
    handle.shutdown();
}

/// The round executor's determinism contract: a mixed-sampler, mixed-steps,
/// mixed-n workload served with 1 worker produces bit-identical images per
/// request to the same workload served with N workers. `submit_many` pins
/// the round composition (all requests join round one), so the only thing
/// varying across runs is worker-pool scheduling — which must not matter.
#[test]
fn parallel_round_executor_is_bit_identical_to_sequential() {
    let Some(dir) = artifacts() else { return };
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let info = pl.manifest.model("ddim16").unwrap().clone();
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(msfp::model::ParamStore::load_init(&info, &dir).unwrap().flat);
    let mut rng = Rng::new(7);
    let mut qp = Vec::new();
    for _ in 0..info.n_layers {
        qp.extend_from_slice(&[1.0, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
    }
    let qs = QuantState {
        qparams: qp,
        lora: vec![0.0; info.lora_size],
        router: Router::init(&info, &mut rng),
        hub_mask: vec![1.0, 1.0, 0.0, 0.0],
        strategy: AllocStrategy::Learned,
        t_total: 100,
    };

    // ≥ 8 concurrent requests, ≥ 2 distinct t per round (mixed step
    // counts and samplers), mixed n
    let workload = || -> Vec<Request> {
        (0..10u64)
            .map(|i| {
                let mut r = Request::new(0, 1 + (i as usize % 3), if i % 2 == 0 { 4 } else { 6 });
                r.seed = 100 + i;
                r.sampler = match i % 3 {
                    0 => SamplerKind::Ddim,
                    1 => SamplerKind::Plms,
                    _ => SamplerKind::DpmSolver2,
                };
                r
            })
            .collect()
    };

    let run = |workers: usize| -> Vec<Vec<u32>> {
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg {
                seed: 11,
                workers,
                ..ServerCfg::new(ServeMode::Quant(qs.clone()))
            },
        );
        let rxs = handle.submit_many(workload()).unwrap();
        let out = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap_done().images.iter().map(|v| v.to_bits()).collect())
            .collect();
        let m = handle.shutdown();
        assert_eq!(m.images_done, workload().iter().map(|r| r.n).sum::<usize>());
        out
    };

    let seq = run(1);
    for workers in [2usize, 4] {
        assert_eq!(seq, run(workers), "workers={workers} changed output bits");
    }
}

/// The packed-backend parity pin: the native nibble-packed serving path
/// (`Backend::Packed`, fused dequantize-matmul in Rust) reproduces the
/// compiled fake-qdq XLA graph (`Backend::Graph`, the oracle) elementwise
/// within a tight tolerance on the standard mixed-sampler workload. The
/// two backends share bit-exact quantized weights (the code table IS the
/// qdq image); the residual difference is pure f32 summation-order drift
/// through ~4-6 denoising steps.
#[test]
fn packed_backend_serving_matches_graph_oracle() {
    let Some(dir) = artifacts() else { return };
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let info = pl.manifest.model("ddim16").unwrap().clone();
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(msfp::model::ParamStore::load_init(&info, &dir).unwrap().flat);
    let mut rng = Rng::new(7);
    let mut qp = Vec::new();
    for _ in 0..info.n_layers {
        qp.extend_from_slice(&[1.0, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
    }
    let qs = QuantState {
        qparams: qp,
        lora: vec![0.0; info.lora_size],
        router: Router::init(&info, &mut rng),
        hub_mask: vec![1.0, 1.0, 0.0, 0.0],
        strategy: AllocStrategy::Learned,
        t_total: 100,
    };

    let workload = || -> Vec<Request> {
        (0..10u64)
            .map(|i| {
                let mut r = Request::new(0, 1 + (i as usize % 3), if i % 2 == 0 { 4 } else { 6 });
                r.seed = 100 + i;
                r.sampler = match i % 3 {
                    0 => SamplerKind::Ddim,
                    1 => SamplerKind::Plms,
                    _ => SamplerKind::DpmSolver2,
                };
                r
            })
            .collect()
    };

    let run = |backend: Backend| -> (Vec<Vec<f32>>, coordinator::Metrics) {
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg {
                seed: 11,
                backend,
                ..ServerCfg::new(ServeMode::Quant(qs.clone()))
            },
        );
        let rxs = handle.submit_many(workload()).unwrap();
        let out = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap_done().images)
            .collect();
        (out, handle.shutdown())
    };

    let (graph, mg) = run(Backend::Graph);
    let (packed, mp) = run(Backend::Packed);
    assert_eq!(mg.backend, "graph");
    assert_eq!(mp.backend, "packed");
    assert_eq!(mg.packed_bytes, 0, "graph backend must not build packed weights");
    assert!(mp.packed_bytes > 0, "packed backend reported no resident packed bytes");

    assert_eq!(graph.len(), packed.len());
    let (mut max_abs, mut sum_abs, mut n, mut energy) = (0.0f32, 0.0f64, 0usize, 0.0f64);
    for (g, p) in graph.iter().zip(&packed) {
        assert_eq!(g.len(), p.len());
        for (a, b) in g.iter().zip(p) {
            assert!(b.is_finite(), "packed backend produced non-finite pixel");
            let d = (a - b).abs();
            max_abs = max_abs.max(d);
            sum_abs += d as f64;
            energy += (a.abs() as f64).max(b.abs() as f64);
            n += 1;
        }
    }
    // pinned parity budget: summation-order drift only, no systematic bias
    assert!(max_abs <= 2e-2, "packed vs graph max |diff| {max_abs} > 2e-2");
    assert!(
        sum_abs / n as f64 <= 2e-3,
        "packed vs graph mean |diff| {} > 2e-3",
        sum_abs / n as f64
    );
    assert!(energy / n as f64 > 1e-3, "outputs are near-zero; parity check is vacuous");
}

/// The FP mixed-t batching satellite's end-to-end pin: a mixed-steps FP
/// workload (requests at different denoising phases every round) served
/// with mixed-t planning produces bit-identical images to same-t planning
/// — the FP graph computes each sample from its own (x, t, cond) — while
/// packing the same work into fewer, fuller batches.
#[test]
fn fp_mixed_t_batching_is_bit_identical_and_cuts_evals() {
    let Some(dir) = artifacts() else { return };
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let info = pl.manifest.model("ddim16").unwrap().clone();
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(msfp::model::ParamStore::load_init(&info, &dir).unwrap().flat);

    // every request runs a different step count => its tau sequence hits
    // distinct t's, so same-t planning degenerates to one singleton batch
    // per request per round while mixed-t packs them together
    let workload = || -> Vec<Request> {
        (0..8u64)
            .map(|i| {
                let mut r = Request::new(0, 1, 3 + i as usize);
                r.seed = 40 + i;
                r
            })
            .collect()
    };
    let run = |mixed: bool| {
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg { seed: 5, fp_mixed_t: mixed, ..ServerCfg::new(ServeMode::Fp) },
        );
        let rxs = handle.submit_many(workload()).unwrap();
        let images: Vec<Vec<u32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap_done().images.iter().map(|v| v.to_bits()).collect())
            .collect();
        (images, handle.shutdown())
    };

    let (same_imgs, same_m) = run(false);
    let (mixed_imgs, mixed_m) = run(true);
    assert_eq!(same_imgs, mixed_imgs, "mixed-t planning changed FP output bits");
    assert!(
        mixed_m.evals < same_m.evals,
        "mixed-t did not cut batch evals: {} vs {}",
        mixed_m.evals,
        same_m.evals
    );
    assert!(mixed_m.mean_batch() > same_m.mean_batch());
}

/// Serving-side online recalibration: a drifted activation stream fed into
/// the sketch handle triggers a background drift check and a between-
/// rounds qparams hot-swap; an undrifted stream must swap nothing and
/// leave output bits untouched.
#[test]
fn serving_recalibration_hot_swaps_on_drift_only() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::ServeRecal;
    use msfp::quant::msfp::{Method, QuantOpts};
    use msfp::recal::SketchSet;
    use std::sync::Mutex;

    std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_integ_recal"));
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p = pl.prepare(Corpus::CifarSyn).unwrap();
    let info = p.info.clone();
    let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4)
        .with_io_8bit(&info.io_layer_indices());
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(p.params.clone());

    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;

    let workload = || -> Vec<Request> {
        (0..6u64)
            .map(|i| {
                let mut r = Request::new(0, 2, 6);
                r.seed = 60 + i;
                r
            })
            .collect()
    };

    // run: serve the workload with (optionally) a recal config whose
    // sketches replay each layer's calibration stream, `shift`ed
    let run = |with_recal: bool, shift: f32| {
        let session = pl.build_session(&p).unwrap();
        let q = pl.quantize_with_session(&p, &session, &spec).unwrap();
        let recal = with_recal.then(|| {
            let sketches = Arc::new(Mutex::new(SketchSet::new(
                info.n_layers,
                4,
                256,
                pl.sched.t_total,
                17,
            )));
            {
                let mut set = sketches.lock().unwrap();
                let mut rng = Rng::new(18);
                for (l, c) in session.calib().iter().enumerate() {
                    for chunk in c.acts.chunks(128) {
                        let t = rng.range(0.0, pl.sched.t_total as f32);
                        let vals: Vec<f32> = chunk.iter().map(|v| v + shift).collect();
                        set.observe(l, t, &vals);
                    }
                    // replay the exact extrema too: the baseline min/max
                    // come from the calib graph's full-tensor capture,
                    // which the subsampled acts don't always reach
                    set.widen_layer(l, 0.0, c.min + shift, c.max + shift);
                }
            }
            let mut r = ServeRecal::new(session, opts.clone(), sketches);
            r.every_rounds = 1;
            r
        });
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            // workers=1 runs the background check in-line on the scheduler
            // thread, so "a swap lands before the workload drains" is
            // deterministic rather than a pool-timing race
            ServerCfg {
                seed: 21,
                workers: 1,
                recal,
                ..ServerCfg::new(ServeMode::Quant(q.state))
            },
        );
        let rxs = handle.submit_many(workload()).unwrap();
        let images: Vec<Vec<u32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap_done().images.iter().map(|v| v.to_bits()).collect())
            .collect();
        (images, handle.shutdown())
    };

    // no recal vs undrifted recal: checks run, nothing swaps, bits agree
    let (base_imgs, base_m) = run(false, 0.0);
    assert_eq!(base_m.recal_checks, 0);
    let (clean_imgs, clean_m) = run(true, 0.0);
    assert!(clean_m.recal_checks > 0, "cadence never checked");
    assert_eq!(clean_m.recal_swaps, 0, "undrifted stream must not swap");
    assert_eq!(base_imgs, clean_imgs, "an idle recal config changed output bits");

    // drifted stream: at least one swap lands and serving stays healthy
    let (drift_imgs, drift_m) = run(true, 1.0);
    assert!(drift_m.recal_swaps >= 1, "drift never swapped: {}", drift_m.report());
    assert!(drift_m.recal_layers >= 1);
    for img in &drift_imgs {
        assert!(img.iter().all(|b| f32::from_bits(*b).is_finite()));
    }
    // the hot-swap audit trail: one record per landed swap, carrying a
    // real qparams fingerprint transition and the drifted layer set the
    // detector scored — the postmortem answer to "what changed, when, why"
    assert_eq!(clean_m.swap_audits.len(), 0, "undrifted stream must not record audits");
    assert_eq!(
        drift_m.swap_audits.len(),
        drift_m.recal_swaps,
        "every swap must leave an audit record: {}",
        drift_m.report()
    );
    let audit = &drift_m.swap_audits[0];
    assert_ne!(audit.old_fp, audit.new_fp, "audited swap did not change the qparams");
    assert!(!audit.drifted.is_empty(), "audit lost its drifted layers");
    assert!(audit.drifted.iter().all(|&(_, score)| score > 0.0), "drift scores must be real");
    assert_eq!(
        drift_m.swap_audits.iter().map(|a| a.drifted.len()).sum::<usize>(),
        drift_m.recal_layers,
        "audit layer sets disagree with the recal_layers counter"
    );
    assert_eq!(
        Some(audit.round as usize),
        drift_m.first_swap_round,
        "first audit round disagrees with first_swap_round"
    );
    std::env::remove_var("MSFP_RUNS");
}

/// The shadow prober's determinism contract: with a probe budget, serving
/// output bits are untouched (probing is a pure observer), the fed sketch
/// window is bit-identical for 1 vs N workers (selection keyed by request
/// id + round, feeding in submission order), and `probe_budget: 0` serving
/// is bit-identical to the pre-prober coordinator.
#[test]
fn shadow_prober_is_deterministic_and_budget_zero_is_bit_identical() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::{Metrics, ServeRecal};
    use msfp::quant::msfp::{Method, QuantOpts};
    use msfp::recal::{RecalPlanner, SketchSet};
    use std::sync::Mutex;

    std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_integ_prober"));
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p = pl.prepare(Corpus::CifarSyn).unwrap();
    let info = p.info.clone();
    let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4)
        .with_io_8bit(&info.io_layer_indices());
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(p.params.clone());
    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;

    let workload = || -> Vec<Request> {
        (0..6u64)
            .map(|i| {
                let mut r = Request::new(0, 2, 6);
                r.seed = 80 + i;
                r
            })
            .collect()
    };

    let run = |workers: usize, budget: usize| -> (Vec<Vec<u32>>, Vec<u8>, Metrics) {
        let session = pl.build_session(&p).unwrap();
        let q = pl.quantize_with_session(&p, &session, &spec).unwrap();
        let sketches = Arc::new(Mutex::new(SketchSet::new(
            info.n_layers,
            4,
            128,
            pl.sched.t_total,
            33,
        )));
        let mut r = ServeRecal::new(session, opts.clone(), Arc::clone(&sketches));
        // pure producer test: live traffic differs from the synthetic
        // calibration baseline, so park the detector (astronomical
        // threshold, cadence beyond the run) to keep swaps out of the
        // comparison
        r.planner = RecalPlanner { threshold: f32::MAX, ..Default::default() };
        r.every_rounds = 10_000;
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg {
                seed: 9,
                workers,
                probe_budget: budget,
                recal: Some(r),
                ..ServerCfg::new(ServeMode::Quant(q.state))
            },
        );
        let rxs = handle.submit_many(workload()).unwrap();
        let images: Vec<Vec<u32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap_done().images.iter().map(|v| v.to_bits()).collect())
            .collect();
        let m = handle.shutdown();
        let bytes = sketches.lock().unwrap().to_bytes();
        (images, bytes, m)
    };

    let (img_off, sk_off, m_off) = run(1, 0);
    assert_eq!(m_off.probes, 0);
    let (img_on, sk_on, m_on) = run(1, 2);
    assert_eq!(img_off, img_on, "probing changed served output bits");
    assert!(m_on.probes > 0, "no probes submitted: {}", m_on.report());
    assert!(m_on.probes_skipped > 0, "budget gate never tripped (6 cands, budget 2)");
    assert_eq!(m_on.probes_failed, 0, "{}", m_on.report());
    assert_ne!(sk_on, sk_off, "probes fed nothing into the sketch window");
    // worker-count invariance: same probes, same feed order, same window
    let (img_par, sk_par, m_par) = run(4, 2);
    assert_eq!(img_on, img_par, "workers changed served bits");
    assert_eq!(sk_on, sk_par, "sketch feeding depended on worker timing");
    assert_eq!(m_on.probes, m_par.probes);
    assert_eq!(m_on.probes_skipped, m_par.probes_skipped);
    std::env::remove_var("MSFP_RUNS");
}

/// The restart-resume contract: a server whose drift window was persisted
/// mid-drift and restored after a "kill" makes the same hot-swap decision
/// (same round, same layers) and serves the same bits as a server that
/// never went down.
#[test]
fn server_restart_resumes_sketch_window_and_hot_swap_decisions() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::{Metrics, ServeRecal};
    use msfp::quant::msfp::{Method, QuantOpts, StateDir};
    use msfp::recal::SketchSet;
    use std::sync::Mutex;

    std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_integ_restart"));
    let state_root = std::env::temp_dir().join("msfp_integ_restart_state");
    let _ = std::fs::remove_dir_all(&state_root);
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p = pl.prepare(Corpus::CifarSyn).unwrap();
    let info = p.info.clone();
    let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4)
        .with_io_8bit(&info.io_layer_indices());
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(p.params.clone());
    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;

    let workload = || -> Vec<Request> {
        (0..6u64)
            .map(|i| {
                let mut r = Request::new(0, 2, 6);
                r.seed = 60 + i;
                r
            })
            .collect()
    };

    // the mid-drift window: every layer's calibration stream replayed
    // shifted (same construction as the PR 4 drift test)
    let drifted_window = |calib: &[msfp::quant::msfp::LayerCalib]| -> SketchSet {
        let mut set = SketchSet::new(info.n_layers, 4, 256, pl.sched.t_total, 17);
        let mut rng = Rng::new(18);
        for (l, c) in calib.iter().enumerate() {
            for chunk in c.acts.chunks(128) {
                let t = rng.range(0.0, pl.sched.t_total as f32);
                let vals: Vec<f32> = chunk.iter().map(|v| v + 1.0).collect();
                set.observe(l, t, &vals);
            }
            set.widen_layer(l, 0.0, c.min + 1.0, c.max + 1.0);
        }
        set
    };

    // serve the workload (workers=1: the inline drift check makes swap
    // timing deterministic); `submit` = false runs zero requests (the
    // pre-kill server that only persists its window on shutdown)
    let serve = |window: SketchSet,
                 sd: Option<StateDir>,
                 submit: bool|
     -> (Vec<Vec<u32>>, Metrics) {
        let session = pl.build_session(&p).unwrap();
        let q = pl.quantize_with_session(&p, &session, &spec).unwrap();
        let sketches = Arc::new(Mutex::new(window));
        let mut r = ServeRecal::new(session, opts.clone(), sketches);
        r.every_rounds = 1;
        r.state_dir = sd;
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg {
                seed: 21,
                workers: 1,
                recal: Some(r),
                ..ServerCfg::new(ServeMode::Quant(q.state))
            },
        );
        let images: Vec<Vec<u32>> = if submit {
            let rxs = handle.submit_many(workload()).unwrap();
            rxs.into_iter()
                .map(|rx| rx.recv().unwrap().unwrap_done().images.iter().map(|v| v.to_bits()).collect())
                .collect()
        } else {
            Vec::new()
        };
        (images, handle.shutdown())
    };

    let session = pl.build_session(&p).unwrap();
    let window = drifted_window(session.calib());
    drop(session);

    // run A: uninterrupted — the fed window triggers a hot-swap mid-serve
    let (imgs_a, m_a) = serve(window.clone(), None, true);
    assert!(m_a.recal_swaps >= 1, "no swap in the uninterrupted run: {}", m_a.report());
    assert!(m_a.first_swap_round.is_some());

    // run B: "kill" a server that accumulated the same window but served
    // nothing — its only trace is the persisted sketch snapshot ...
    let sd = StateDir::new(&state_root);
    let (_, m_pre) = serve(window.clone(), Some(sd.clone()), false);
    assert_eq!(m_pre.recal_swaps, 0);
    assert!(sd.sketch_path().exists(), "shutdown must persist the window");

    // ... then restart blind (an EMPTY in-memory window) with the same
    // state dir: the restored snapshot must reproduce run A exactly
    let empty = SketchSet::new(info.n_layers, 4, 256, pl.sched.t_total, 17);
    let (imgs_b, m_b) = serve(empty, Some(sd.clone()), true);
    assert_eq!(m_b.recal_swaps, m_a.recal_swaps, "restart changed swap count");
    assert_eq!(m_b.recal_layers, m_a.recal_layers, "restart changed swapped layers");
    assert_eq!(m_b.first_swap_round, m_a.first_swap_round, "restart changed swap round");
    assert_eq!(imgs_a, imgs_b, "restart changed served bits");

    // after the swap the checkpoint carries the recalibrated quant state
    assert!(sd.quant_path().exists(), "swap must checkpoint the quant state");
    let restored = QuantState::load(&info, &sd.quant_path()).unwrap();
    assert_eq!(restored.qparams.len(), info.n_layers * 8);
    std::env::remove_var("MSFP_RUNS");
}

/// The overload contract: against a queue budget with a pre-built
/// degradation ladder, best-effort requests past their deadline are
/// explicitly shed, interactive requests are downgraded (admission step
/// cuts + ladder-rung rounds, deeper backlog → coarser rung), and every
/// decision — plus each survivor's output bits — is a pure function of
/// the queue snapshot, identical for 1 vs N workers.
#[test]
fn overload_sheds_and_degrades_deterministically_across_workers() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::{degraded_state, LadderRung, Response, SloCfg, SloClass};
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let info = pl.manifest.model("ddim16").unwrap().clone();
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(msfp::model::ParamStore::load_init(&info, &dir).unwrap().flat);
    let mut rng = Rng::new(7);
    let mut qp = Vec::new();
    for _ in 0..info.n_layers {
        qp.extend_from_slice(&[1.0, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
    }
    let qs = QuantState {
        qparams: qp.clone(),
        lora: vec![0.0; info.lora_size],
        router: Router::init(&info, &mut rng),
        hub_mask: vec![1.0, 1.0, 0.0, 0.0],
        strategy: AllocStrategy::Learned,
        t_total: 100,
    };
    // two-rung ladder of stand-ins: same state, progressively coarser
    // qparams (what W3/W2 re-searches would hand back via
    // `QuantSession::degraded_qparams`). Backlog depth picks the rung.
    let mut deg_qp = qp.clone();
    for v in deg_qp.iter_mut().step_by(2) {
        *v *= 0.5;
    }
    let mut deg_qp2 = qp;
    for v in deg_qp2.iter_mut().step_by(2) {
        *v *= 0.25;
    }
    let ladder = vec![
        LadderRung { wbits: 3, abits: 4, state: degraded_state(&qs, deg_qp) },
        LadderRung { wbits: 2, abits: 4, state: degraded_state(&qs, deg_qp2) },
    ];

    // backlog of 18 samples against a budget of 4: overloaded from round
    // one. Classes cycle; the last request is a best-effort job whose
    // 1-round deadline cannot be met — it must be shed, not hung.
    let workload = || -> Vec<Request> {
        let mut v: Vec<Request> = (0..9u64)
            .map(|i| {
                let mut r = Request::new(i, 1 + (i as usize % 2), 4 + (i as usize % 3))
                    .with_slo(match i % 3 {
                        0 => SloClass::Interactive,
                        1 => SloClass::Batch,
                        _ => SloClass::BestEffort,
                    });
                r.seed = 200 + i;
                r
            })
            .collect();
        let mut doomed = Request::new(99, 4, 6).with_slo(SloClass::BestEffort);
        doomed.seed = 999;
        doomed.deadline_rounds = 1;
        v.push(doomed);
        v
    };

    #[derive(Debug, PartialEq)]
    enum Out {
        Done { bits: Vec<u32>, degraded: bool },
        Shed(String),
    }
    let run = |workers: usize| {
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg {
                seed: 13,
                workers,
                slo: SloCfg { queue_budget: 4, step_cut: 2, ladder: ladder.clone() },
                ..ServerCfg::new(ServeMode::Quant(qs.clone()))
            },
        );
        let rxs = handle.submit_many(workload()).unwrap();
        let outs: Vec<Out> = rxs
            .into_iter()
            .map(|rx| match rx.recv().unwrap() {
                Response::Done(c) => Out::Done {
                    bits: c.images.iter().map(|v| v.to_bits()).collect(),
                    degraded: c.degraded,
                },
                Response::Shed { class, reason, .. } => Out::Shed(format!("{class:?}: {reason}")),
            })
            .collect();
        (outs, handle.shutdown())
    };

    let (outs, m) = run(1);
    assert!(
        matches!(&outs[outs.len() - 1], Out::Shed(s) if s.contains("deadline")),
        "impossible-deadline best-effort request was not shed: {:?}",
        outs.last()
    );
    assert!(m.shed_total() >= 1, "{}", m.report());
    assert!(m.downgraded_rounds >= 1, "no overloaded round degraded: {}", m.report());
    assert!(m.downgraded_steps >= 1, "no admission step cut landed: {}", m.report());
    assert!(
        outs.iter().any(|o| matches!(o, Out::Done { degraded: true, .. })),
        "no completion rode the degraded variant"
    );
    // the 18-sample backlog against budget 4 opens deep enough to hit the
    // coarsest rung, and drains through the milder one on the way down
    assert_eq!(m.rung_rounds.len(), 2, "{}", m.report());
    assert!(m.rung_rounds[1] >= 1, "deep backlog never hit the coarse rung: {}", m.report());
    assert_eq!(
        m.rung_rounds.iter().sum::<usize>(),
        m.downgraded_rounds,
        "every degraded round must land on exactly one rung: {}",
        m.report()
    );
    for o in &outs {
        if let Out::Done { bits, .. } = o {
            assert!(bits.iter().all(|b| f32::from_bits(*b).is_finite()));
        }
    }
    for workers in [2usize, 4] {
        let (outs_n, m_n) = run(workers);
        assert_eq!(outs, outs_n, "workers={workers} changed shed/downgrade outcomes");
        assert_eq!(m.shed, m_n.shed, "workers={workers} changed shed counts");
        assert_eq!(m.downgraded_rounds, m_n.downgraded_rounds);
        assert_eq!(m.downgraded_steps, m_n.downgraded_steps);
        assert_eq!(m.rung_rounds, m_n.rung_rounds, "workers={workers} changed rung choices");
        assert_eq!(m.images_done, m_n.images_done);
        assert_eq!(m.rounds, m_n.rounds, "workers={workers} changed round count");
    }
}

/// The flight recorder's determinism contract: the *logical* event trace
/// (wall-clock annotations stripped) of an overload workload — admits,
/// sheds, rung changes, per-round summaries, completions — is
/// byte-identical between a 1-worker and a 4-worker server. The shutdown
/// postmortem (`trace.mtr` + `metrics.jsonl`) must land in the obs dir,
/// reload through the versioned parser, and stay loud when corrupted.
#[test]
fn flight_recorder_trace_is_bit_identical_across_workers() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::{degraded_state, LadderRung, ObsCfg, SloCfg, SloClass};
    use msfp::obs::Trace;
    use msfp::quant::msfp::StateDir;
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let info = pl.manifest.model("ddim16").unwrap().clone();
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(msfp::model::ParamStore::load_init(&info, &dir).unwrap().flat);
    let mut rng = Rng::new(7);
    let mut qp = Vec::new();
    for _ in 0..info.n_layers {
        qp.extend_from_slice(&[1.0, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
    }
    let qs = QuantState {
        qparams: qp.clone(),
        lora: vec![0.0; info.lora_size],
        router: Router::init(&info, &mut rng),
        hub_mask: vec![1.0, 1.0, 0.0, 0.0],
        strategy: AllocStrategy::Learned,
        t_total: 100,
    };
    let mut deg_qp = qp;
    for v in deg_qp.iter_mut().step_by(2) {
        *v *= 0.5;
    }
    let ladder = vec![LadderRung { wbits: 3, abits: 4, state: degraded_state(&qs, deg_qp) }];
    // overloaded from round one (backlog over a budget of 4, mixed SLO
    // classes), plus one impossible-deadline request so the trace carries
    // at least one shed — the event mix exercises most kinds
    let workload = || -> Vec<Request> {
        let mut v: Vec<Request> = (0..8u64)
            .map(|i| {
                let mut r = Request::new(i, 1 + (i as usize % 2), 4 + (i as usize % 3))
                    .with_slo(match i % 3 {
                        0 => SloClass::Interactive,
                        1 => SloClass::Batch,
                        _ => SloClass::BestEffort,
                    });
                r.seed = 300 + i;
                r
            })
            .collect();
        let mut doomed = Request::new(99, 3, 6).with_slo(SloClass::BestEffort);
        doomed.seed = 999;
        doomed.deadline_rounds = 1;
        v.push(doomed);
        v
    };
    let run = |workers: usize, root: &std::path::Path| {
        let _ = std::fs::remove_dir_all(root);
        std::fs::create_dir_all(root).unwrap();
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg {
                seed: 13,
                workers,
                slo: SloCfg { queue_budget: 4, step_cut: 2, ladder: ladder.clone() },
                obs: ObsCfg { dir: Some(StateDir::new(root)), ..ObsCfg::default() },
                ..ServerCfg::new(ServeMode::Quant(qs.clone()))
            },
        );
        let rxs = handle.submit_many(workload()).unwrap();
        for rx in rxs {
            let _ = rx.recv().unwrap();
        }
        let m = handle.shutdown();
        let trace =
            Trace::load(&StateDir::new(root).trace_path()).expect("postmortem trace reloads");
        (trace, m)
    };

    let root1 = std::env::temp_dir().join("msfp_integ_trace_w1");
    let root4 = std::env::temp_dir().join("msfp_integ_trace_w4");
    let (t1, m1) = run(1, &root1);
    let (t4, m4) = run(4, &root4);

    // the recorder saw real traffic and the shutdown dump landed
    assert!(m1.trace_events > 0, "recorder never saw an event: {}", m1.report());
    assert_eq!(m1.trace_dropped, 0, "ring overflowed on a tiny workload");
    assert!(m1.postmortems >= 1, "shutdown never dumped a postmortem");
    assert!(StateDir::new(&root1).telemetry_path().exists(), "telemetry series missing");

    // the logical trace is byte-identical for 1 vs 4 workers: wall-clock
    // annotations differ, every decision event agrees bit-for-bit
    assert_eq!(m1.trace_events, m4.trace_events, "event counts diverged across workers");
    assert_eq!(
        t1.logical_bytes(),
        t4.logical_bytes(),
        "logical traces diverged across worker counts:\n-- w1 --\n{}\n-- w4 --\n{}",
        t1.render(),
        t4.render()
    );

    // the human rendering names the decisions the workload forced
    let txt = t1.render();
    for needle in ["admit", "shed", "round", "done", "shutdown"] {
        assert!(txt.contains(needle), "trace rendering lost {needle} events:\n{txt}");
    }

    // a truncated dump stays loud with its distinct parse error
    let tp = StateDir::new(&root1).trace_path();
    let bytes = std::fs::read(&tp).unwrap();
    std::fs::write(&tp, &bytes[..bytes.len() / 2]).unwrap();
    let err = Trace::load(&tp).unwrap_err();
    assert!(format!("{err:#}").contains("truncated trace"), "unexpected error: {err:#}");
    let _ = std::fs::remove_dir_all(&root1);
    let _ = std::fs::remove_dir_all(&root4);
}

/// The fault-injection contract: a seeded `FaultPlan` forces the same
/// batch failures for any worker count, so retry counts, backoff windows
/// and every request's recovery (or exhaustion shed) replay bit-identically
/// — a crash/retry storm is a reproducible test fixture, not flake.
#[test]
fn fault_plan_retries_are_deterministic_across_workers() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::{FaultPlan, Response};
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let info = pl.manifest.model("ddim16").unwrap().clone();
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(msfp::model::ParamStore::load_init(&info, &dir).unwrap().flat);
    let mut rng = Rng::new(7);
    let mut qp = Vec::new();
    for _ in 0..info.n_layers {
        qp.extend_from_slice(&[1.0, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
    }
    let qs = QuantState {
        qparams: qp,
        lora: vec![0.0; info.lora_size],
        router: Router::init(&info, &mut rng),
        hub_mask: vec![1.0, 1.0, 0.0, 0.0],
        strategy: AllocStrategy::Learned,
        t_total: 100,
    };
    let workload = || -> Vec<Request> {
        (0..8u64)
            .map(|i| {
                let mut r = Request::new(i, 1 + (i as usize % 2), 4 + (i as usize % 3));
                r.seed = 300 + i;
                r
            })
            .collect()
    };
    let run = |workers: usize| {
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg {
                seed: 17,
                workers,
                // ~30% of batches fail: enough pressure to exercise the
                // retry/backoff machinery on a short workload
                faults: FaultPlan { fail_per_mille: 300, ..FaultPlan::new(77) },
                ..ServerCfg::new(ServeMode::Quant(qs.clone()))
            },
        );
        let rxs = handle.submit_many(workload()).unwrap();
        let outs: Vec<(u64, Option<Vec<u32>>)> = rxs
            .into_iter()
            .map(|rx| match rx.recv().unwrap() {
                Response::Done(c) => {
                    (c.id, Some(c.images.iter().map(|v| v.to_bits()).collect()))
                }
                Response::Shed { id, .. } => (id, None),
            })
            .collect();
        (outs, handle.shutdown())
    };

    let (outs, m) = run(1);
    assert!(m.faults_injected > 0, "fault plan never fired: {}", m.report());
    assert!(m.retries > 0, "injected failures never retried: {}", m.report());
    // the engine's compile retry budget surfaces through the metrics
    assert!(m.compile_attempts >= 1, "{}", m.report());
    assert_eq!(m.compile_exhausted, 0, "{}", m.report());
    for workers in [4usize] {
        let (outs_n, m_n) = run(workers);
        assert_eq!(outs, outs_n, "workers={workers} changed fault-recovery outcomes");
        assert_eq!(m.retries, m_n.retries, "workers={workers} changed retry count");
        assert_eq!(m.faults_injected, m_n.faults_injected);
        assert_eq!(m.shed, m_n.shed);
        assert_eq!(m.rounds, m_n.rounds);
    }
}

/// A client that drops its receiver walks away from its request: the
/// scheduler retires it at plan time instead of burning its remaining
/// rounds, and counts it as cancelled rather than completed.
#[test]
fn client_cancellation_retires_dropped_requests() {
    let Some(dir) = artifacts() else { return };
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let info = pl.manifest.model("ddim16").unwrap().clone();
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(msfp::model::ParamStore::load_init(&info, &dir).unwrap().flat);
    let handle = coordinator::spawn(
        den,
        info,
        pl.sched.clone(),
        params,
        ServerCfg { seed: 3, ..ServerCfg::new(ServeMode::Fp) },
    );
    // 64 steps: far more rounds than the short request needs, so the
    // plan-time sweep must catch the dropped receiver long before the
    // request could finish on its own
    let mut long = Request::new(0, 2, 64);
    long.seed = 1;
    let rx_long = handle.submit(long).unwrap();
    let mut short = Request::new(1, 1, 3);
    short.seed = 2;
    let rx_short = handle.submit(short).unwrap();
    drop(rx_long); // the client walks away
    let r = rx_short.recv().unwrap().unwrap_done();
    assert_eq!(r.n, 1);
    let m = handle.shutdown();
    assert_eq!(m.cancelled, 1, "dropped receiver was not retired: {}", m.report());
    assert_eq!(m.images_done, 1, "cancelled request still completed: {}", m.report());
}

/// A corrupt (truncated) persisted sketch window must not take the server
/// down: it warns, cold-starts the in-memory window, serves normally and
/// re-persists a valid snapshot on shutdown. The explicit `SketchSet::load`
/// keeps its distinct error so callers can tell corruption from absence.
#[test]
fn truncated_sketch_state_cold_starts_and_recovers() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::ServeRecal;
    use msfp::quant::msfp::{Method, QuantOpts, StateDir};
    use msfp::recal::SketchSet;
    use std::sync::Mutex;

    std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_integ_trunc"));
    let state_root = std::env::temp_dir().join("msfp_integ_trunc_state");
    let _ = std::fs::remove_dir_all(&state_root);
    std::fs::create_dir_all(&state_root).unwrap();
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p = pl.prepare(Corpus::CifarSyn).unwrap();
    let info = p.info.clone();
    let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4)
        .with_io_8bit(&info.io_layer_indices());
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(p.params.clone());
    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;
    let session = pl.build_session(&p).unwrap();
    let q = pl.quantize_with_session(&p, &session, &spec).unwrap();

    // persist a valid window, then truncate it in place — a crash mid-write
    let sd = StateDir::new(&state_root);
    let valid = SketchSet::new(info.n_layers, 4, 128, pl.sched.t_total, 5);
    valid.save(&sd.sketch_path()).unwrap();
    let bytes = std::fs::read(sd.sketch_path()).unwrap();
    std::fs::write(sd.sketch_path(), &bytes[..bytes.len() / 2]).unwrap();

    // the explicit loader stays loud about corruption
    let err = SketchSet::load(&sd.sketch_path()).unwrap_err();
    assert!(format!("{err:#}").contains("parsing"), "unexpected error: {err:#}");

    // the server warns, cold-starts, and serves anyway
    let sketches =
        Arc::new(Mutex::new(SketchSet::new(info.n_layers, 4, 128, pl.sched.t_total, 5)));
    let mut r = ServeRecal::new(session, opts, sketches);
    r.every_rounds = 10_000; // park the detector: this test is about restore
    r.state_dir = Some(sd.clone());
    let handle = coordinator::spawn(
        den,
        info.clone(),
        pl.sched.clone(),
        params,
        ServerCfg { seed: 23, workers: 1, recal: Some(r), ..ServerCfg::new(ServeMode::Quant(q.state)) },
    );
    let rxs = handle
        .submit_many(
            (0..3u64)
                .map(|i| {
                    let mut r = Request::new(i, 1, 3);
                    r.seed = 70 + i;
                    r
                })
                .collect(),
        )
        .unwrap();
    for rx in rxs {
        let c = rx.recv().unwrap().unwrap_done();
        assert!(c.images.iter().all(|v| v.is_finite()));
    }
    let m = handle.shutdown();
    assert_eq!(m.images_done, 3);
    // shutdown re-persisted a valid window over the corrupt file
    SketchSet::load(&sd.sketch_path())
        .expect("shutdown must overwrite the corrupt window with a valid snapshot");
    std::env::remove_var("MSFP_RUNS");
}

/// The crash-consistency soak: a server killed at *any* seeded storage
/// fault point — torn checkpoint write, transient/permanent EIO, crash
/// before rename — restarts from its StateDir and reproduces an
/// uninterrupted run's hot-swap decisions (round, layer count) and served
/// image bits exactly. Failed checkpoint writes must leave the previous
/// complete snapshot byte-identical on disk, never strand a tmp file, and
/// surface in the `ckpt_fails`/`ckpt_retries` counters.
#[test]
fn chaos_checkpoint_kill_points_preserve_restart_decisions() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::{Metrics, ServeRecal};
    use msfp::quant::msfp::{Method, QuantOpts, StateDir};
    use msfp::recal::SketchSet;
    use msfp::util::io::FaultFs;
    use std::sync::Mutex;

    std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_integ_chaos"));
    let state_root = std::env::temp_dir().join("msfp_integ_chaos_state");
    let _ = std::fs::remove_dir_all(&state_root);
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p = pl.prepare(Corpus::CifarSyn).unwrap();
    let info = p.info.clone();
    let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4)
        .with_io_8bit(&info.io_layer_indices());
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(p.params.clone());
    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;

    let workload = || -> Vec<Request> {
        (0..6u64)
            .map(|i| {
                let mut r = Request::new(0, 2, 6);
                r.seed = 140 + i;
                r
            })
            .collect()
    };
    // the mid-drift window (same construction as the restart test)
    let drifted_window = |calib: &[msfp::quant::msfp::LayerCalib]| -> SketchSet {
        let mut set = SketchSet::new(info.n_layers, 4, 256, pl.sched.t_total, 17);
        let mut rng = Rng::new(18);
        for (l, c) in calib.iter().enumerate() {
            for chunk in c.acts.chunks(128) {
                let t = rng.range(0.0, pl.sched.t_total as f32);
                let vals: Vec<f32> = chunk.iter().map(|v| v + 1.0).collect();
                set.observe(l, t, &vals);
            }
            set.widen_layer(l, 0.0, c.min + 1.0, c.max + 1.0);
        }
        set
    };
    // workers=1: the inline drift check makes swap timing deterministic
    let serve = |window: SketchSet,
                 sd: Option<StateDir>,
                 submit: bool|
     -> (Vec<Vec<u32>>, Metrics) {
        let session = pl.build_session(&p).unwrap();
        let q = pl.quantize_with_session(&p, &session, &spec).unwrap();
        let sketches = Arc::new(Mutex::new(window));
        let mut r = ServeRecal::new(session, opts.clone(), sketches);
        r.every_rounds = 1;
        r.state_dir = sd;
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg {
                seed: 21,
                workers: 1,
                recal: Some(r),
                ..ServerCfg::new(ServeMode::Quant(q.state))
            },
        );
        let images: Vec<Vec<u32>> = if submit {
            let rxs = handle.submit_many(workload()).unwrap();
            rxs.into_iter()
                .map(|rx| rx.recv().unwrap().unwrap_done().images.iter().map(|v| v.to_bits()).collect())
                .collect()
        } else {
            Vec::new()
        };
        (images, handle.shutdown())
    };
    let no_strays = || {
        for e in std::fs::read_dir(&state_root).unwrap() {
            let name = e.unwrap().file_name().to_string_lossy().into_owned();
            assert!(!name.contains(".tmp."), "stray tmp file survived a fault: {name}");
        }
    };

    let session = pl.build_session(&p).unwrap();
    let window = drifted_window(session.calib());
    drop(session);

    // run A: uninterrupted, no state dir — the ground-truth decisions
    let (imgs_a, m_a) = serve(window.clone(), None, true);
    assert!(m_a.recal_swaps >= 1, "no swap in the baseline run: {}", m_a.report());

    // seed the state dir: a server that accumulated the same window but
    // was killed before serving — its only trace is the persisted window
    let sd = StateDir::new(&state_root);
    serve(window.clone(), Some(sd.clone()), false);
    let snap0 = std::fs::read(sd.sketch_path()).unwrap();

    // kill-point matrix: every checkpoint write hits the fault (rate
    // 1000). The restarted server must still reproduce run A bit-exactly
    // — checkpointing is best-effort, never load-bearing for decisions —
    // and the failed writes must leave the seeded snapshot untouched.
    let kill_points = [
        ("torn write", FaultFs { torn_per_mille: 1000, ..FaultFs::new(4) }),
        ("permanent EIO", FaultFs { eio_per_mille: 1000, ..FaultFs::new(4) }),
        ("crash before rename", FaultFs { crash_per_mille: 1000, ..FaultFs::new(4) }),
    ];
    for (kind, plan) in kill_points {
        let guard = plan.install(&state_root);
        let blind = SketchSet::new(info.n_layers, 4, 256, pl.sched.t_total, 17);
        let (imgs_f, m_f) = serve(blind, Some(sd.clone()), true);
        drop(guard);
        assert_eq!(imgs_f, imgs_a, "{kind}: restart changed served bits");
        assert_eq!(m_f.recal_swaps, m_a.recal_swaps, "{kind}: swap count changed");
        assert_eq!(m_f.recal_layers, m_a.recal_layers, "{kind}: swapped layers changed");
        assert_eq!(m_f.first_swap_round, m_a.first_swap_round, "{kind}: swap round changed");
        assert!(m_f.ckpt_fails >= 2, "{kind}: fault never surfaced: {}", m_f.report());
        assert_eq!(
            std::fs::read(sd.sketch_path()).unwrap(),
            snap0,
            "{kind}: a failed checkpoint corrupted the snapshot on disk"
        );
        assert!(!sd.quant_path().exists(), "{kind}: a failed write landed anyway");
        no_strays();
    }

    // transient EIO (seed 0, 600‰): writes clear within the retry cap —
    // the run reproduces A, counts retries, and the checkpoint lands
    let guard = FaultFs { eio_per_mille: 600, ..FaultFs::new(0) }.install(&state_root);
    let (imgs_t, m_t) =
        serve(SketchSet::new(info.n_layers, 4, 256, pl.sched.t_total, 17), Some(sd.clone()), true);
    drop(guard);
    assert_eq!(imgs_t, imgs_a, "transient faults changed served bits");
    assert_eq!(m_t.recal_swaps, m_a.recal_swaps);
    assert_eq!(m_t.ckpt_fails, 0, "transient faults must clear in retries: {}", m_t.report());
    assert!(m_t.ckpt_retries >= 1, "no retry was counted: {}", m_t.report());
    assert!(sd.quant_path().exists(), "retried checkpoint never landed");
    no_strays();

    // final clean restart on the surviving state dir: still run A
    let (imgs_c, m_c) =
        serve(SketchSet::new(info.n_layers, 4, 256, pl.sched.t_total, 17), Some(sd.clone()), true);
    assert_eq!(imgs_c, imgs_a, "clean restart after the storm changed served bits");
    assert_eq!(m_c.recal_swaps, m_a.recal_swaps);
    let restored = QuantState::load(&info, &sd.quant_path()).unwrap();
    assert_eq!(restored.qparams.len(), info.n_layers * 8);
    std::env::remove_var("MSFP_RUNS");
}

/// The live-reconfiguration contract: `ServerHandle::reconfigure` swaps
/// queue budget, step cut and the degradation ladder between rounds of a
/// running server — before it, an overload workload sails through
/// unthrottled; after it, the same workload sheds and degrades — and the
/// whole two-phase sequence replays bit-identically for 1 vs N workers.
#[test]
fn reconfigure_and_ladder_rungs_are_deterministic_across_workers() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::{degraded_state, LadderRung, Response, SloCfg, SloClass};
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let info = pl.manifest.model("ddim16").unwrap().clone();
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(msfp::model::ParamStore::load_init(&info, &dir).unwrap().flat);
    let mut rng = Rng::new(7);
    let mut qp = Vec::new();
    for _ in 0..info.n_layers {
        qp.extend_from_slice(&[1.0, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
    }
    let qs = QuantState {
        qparams: qp.clone(),
        lora: vec![0.0; info.lora_size],
        router: Router::init(&info, &mut rng),
        hub_mask: vec![1.0, 1.0, 0.0, 0.0],
        strategy: AllocStrategy::Learned,
        t_total: 100,
    };
    let mut deg_qp = qp.clone();
    for v in deg_qp.iter_mut().step_by(2) {
        *v *= 0.5;
    }
    let mut deg_qp2 = qp;
    for v in deg_qp2.iter_mut().step_by(2) {
        *v *= 0.25;
    }
    let ladder = vec![
        LadderRung { wbits: 3, abits: 4, state: degraded_state(&qs, deg_qp) },
        LadderRung { wbits: 2, abits: 4, state: degraded_state(&qs, deg_qp2) },
    ];
    let workload = |base: u64| -> Vec<Request> {
        let mut v: Vec<Request> = (0..9u64)
            .map(|i| {
                let mut r = Request::new(i, 1 + (i as usize % 2), 4 + (i as usize % 3))
                    .with_slo(match i % 3 {
                        0 => SloClass::Interactive,
                        1 => SloClass::Batch,
                        _ => SloClass::BestEffort,
                    });
                r.seed = base + i;
                r
            })
            .collect();
        let mut doomed = Request::new(99, 4, 6).with_slo(SloClass::BestEffort);
        doomed.seed = base + 99;
        doomed.deadline_rounds = 1;
        v.push(doomed);
        v
    };
    #[derive(Debug, PartialEq)]
    enum Out {
        Done { bits: Vec<u32>, degraded: bool },
        Shed(String),
    }
    let collect = |rxs: Vec<msfp::coordinator::ResponseRx>| -> Vec<Out> {
        rxs.into_iter()
            .map(|rx| match rx.recv().unwrap() {
                Response::Done(c) => Out::Done {
                    bits: c.images.iter().map(|v| v.to_bits()).collect(),
                    degraded: c.degraded,
                },
                Response::Shed { class, reason, .. } => Out::Shed(format!("{class:?}: {reason}")),
            })
            .collect()
    };
    let run = |workers: usize| {
        // spawned wide open: no budget, no ladder
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg { seed: 13, workers, ..ServerCfg::new(ServeMode::Quant(qs.clone())) },
        );
        // phase 1: the overload workload sails through unthrottled
        let outs1 = collect(handle.submit_many(workload(400)).unwrap());
        // live tighten: budget + step cut + two-rung ladder. Channel
        // order puts this before phase 2's submission, and the scheduler
        // applies it between rounds — so phase 2 runs entirely under the
        // new knobs for any worker count.
        handle
            .reconfigure(SloCfg { queue_budget: 4, step_cut: 2, ladder: ladder.clone() })
            .unwrap();
        // phase 2: the same workload now sheds and degrades
        let outs2 = collect(handle.submit_many(workload(500)).unwrap());
        (outs1, outs2, handle.shutdown())
    };

    let (outs1, outs2, m) = run(1);
    assert!(
        outs1.iter().all(|o| matches!(o, Out::Done { degraded: false, .. })),
        "pre-reconfigure phase must be unthrottled"
    );
    assert!(
        matches!(&outs2[outs2.len() - 1], Out::Shed(s) if s.contains("deadline")),
        "post-reconfigure doomed request was not shed: {:?}",
        outs2.last()
    );
    assert!(
        outs2.iter().any(|o| matches!(o, Out::Done { degraded: true, .. })),
        "no post-reconfigure completion rode a ladder rung"
    );
    assert_eq!(m.reconfigures, 1, "{}", m.report());
    assert!(m.downgraded_rounds >= 1, "{}", m.report());
    assert_eq!(m.rung_rounds.len(), 2, "{}", m.report());
    assert_eq!(m.rung_rounds.iter().sum::<usize>(), m.downgraded_rounds, "{}", m.report());
    for workers in [4usize] {
        let (o1, o2, m_n) = run(workers);
        assert_eq!(outs1, o1, "workers={workers} changed pre-reconfigure outcomes");
        assert_eq!(outs2, o2, "workers={workers} changed post-reconfigure outcomes");
        assert_eq!(m.shed, m_n.shed);
        assert_eq!(m.downgraded_rounds, m_n.downgraded_rounds);
        assert_eq!(m.downgraded_steps, m_n.downgraded_steps);
        assert_eq!(m.rung_rounds, m_n.rung_rounds, "workers={workers} changed rung choices");
        assert_eq!(m.rounds, m_n.rounds);
    }
}

/// A corrupt (truncated) persisted packed blob must not take a packed-
/// backend server down: `PackedModel::load` stays loud with a distinct
/// parse error, the server falls back to rebuilding the packed weights
/// from the f32 store, serves normally, and re-persists a loadable blob
/// for the next start.
#[test]
fn corrupt_packed_blob_falls_back_and_repersists() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::ServeRecal;
    use msfp::quant::msfp::{Method, QuantOpts, StateDir};
    use msfp::quant::PackedModel;
    use msfp::recal::SketchSet;
    use std::sync::Mutex;

    std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_integ_packed_corrupt"));
    let state_root = std::env::temp_dir().join("msfp_integ_packed_corrupt_state");
    let _ = std::fs::remove_dir_all(&state_root);
    std::fs::create_dir_all(&state_root).unwrap();
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p = pl.prepare(Corpus::CifarSyn).unwrap();
    let info = p.info.clone();
    let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4)
        .with_io_8bit(&info.io_layer_indices());
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(p.params.clone());
    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;
    let session = pl.build_session(&p).unwrap();
    let q = pl.quantize_with_session(&p, &session, &spec).unwrap();

    // persist a valid packed blob, then truncate it — a crash mid-update
    let sd = StateDir::new(&state_root);
    let valid = den.packed_blob(&params, &q.state).unwrap();
    std::fs::write(sd.packed_path(), &valid[..valid.len() / 2]).unwrap();
    let err = PackedModel::load(&sd.packed_path()).unwrap_err();
    assert!(format!("{err:#}").contains("parsing"), "unexpected error: {err:#}");

    // the packed-backend server warns, rebuilds from the f32 store, and
    // serves; startup re-persists a loadable blob over the corrupt one
    let sketches =
        Arc::new(Mutex::new(SketchSet::new(info.n_layers, 4, 128, pl.sched.t_total, 5)));
    let mut r = ServeRecal::new(session, opts, sketches);
    r.every_rounds = 10_000; // park the detector: this test is about restore
    r.state_dir = Some(sd.clone());
    let handle = coordinator::spawn(
        Arc::clone(&den),
        info.clone(),
        pl.sched.clone(),
        Arc::clone(&params),
        ServerCfg {
            seed: 23,
            workers: 1,
            recal: Some(r),
            backend: Backend::Packed,
            ..ServerCfg::new(ServeMode::Quant(q.state.clone()))
        },
    );
    let rxs = handle
        .submit_many(
            (0..3u64)
                .map(|i| {
                    let mut r = Request::new(i, 1, 3);
                    r.seed = 170 + i;
                    r
                })
                .collect(),
        )
        .unwrap();
    for rx in rxs {
        let c = rx.recv().unwrap().unwrap_done();
        assert!(c.images.iter().all(|v| v.is_finite()));
    }
    let m = handle.shutdown();
    assert_eq!(m.images_done, 3);
    assert_eq!(m.ckpt_fails, 0, "{}", m.report());
    // the re-persisted blob is complete and byte-identical to a fresh pack
    let reloaded = PackedModel::load(&sd.packed_path())
        .expect("startup must overwrite the corrupt blob with a loadable one");
    assert_eq!(reloaded.to_bytes(), valid, "re-persisted blob drifted from a fresh pack");
    std::env::remove_var("MSFP_RUNS");
}

/// Recal-check fault coverage: an injected panic mid-application discards
/// the half-applied plan (no swap ever lands), clears `inflight` so the
/// check cadence keeps running, and never wedges serving or shutdown; an
/// injected slowdown changes nothing but wall time — swap decisions and
/// served bits stay bit-identical to the fault-free run.
#[test]
fn recal_check_faults_never_wedge_or_half_apply() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::{FaultPlan, Metrics, ServeRecal};
    use msfp::quant::msfp::{Method, QuantOpts};
    use msfp::recal::SketchSet;
    use std::sync::Mutex;

    std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_integ_recal_faults"));
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p = pl.prepare(Corpus::CifarSyn).unwrap();
    let info = p.info.clone();
    let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4)
        .with_io_8bit(&info.io_layer_indices());
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(p.params.clone());
    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;

    let drifted_window = |calib: &[msfp::quant::msfp::LayerCalib]| -> SketchSet {
        let mut set = SketchSet::new(info.n_layers, 4, 256, pl.sched.t_total, 17);
        let mut rng = Rng::new(18);
        for (l, c) in calib.iter().enumerate() {
            for chunk in c.acts.chunks(128) {
                let t = rng.range(0.0, pl.sched.t_total as f32);
                let vals: Vec<f32> = chunk.iter().map(|v| v + 1.0).collect();
                set.observe(l, t, &vals);
            }
            set.widen_layer(l, 0.0, c.min + 1.0, c.max + 1.0);
        }
        set
    };
    let serve = |faults: FaultPlan| -> (Vec<Vec<u32>>, Metrics) {
        let session = pl.build_session(&p).unwrap();
        let q = pl.quantize_with_session(&p, &session, &spec).unwrap();
        let window = drifted_window(session.calib());
        let sketches = Arc::new(Mutex::new(window));
        let mut r = ServeRecal::new(session, opts.clone(), sketches);
        r.every_rounds = 1;
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            ServerCfg {
                seed: 21,
                workers: 1,
                recal: Some(r),
                faults,
                ..ServerCfg::new(ServeMode::Quant(q.state))
            },
        );
        let rxs = handle
            .submit_many(
                (0..6u64)
                    .map(|i| {
                        let mut r = Request::new(0, 2, 6);
                        r.seed = 240 + i;
                        r
                    })
                    .collect(),
            )
            .unwrap();
        let images: Vec<Vec<u32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap_done().images.iter().map(|v| v.to_bits()).collect())
            .collect();
        (images, handle.shutdown())
    };

    // baseline: the drifted window triggers at least one hot-swap
    let (imgs_ok, m_ok) = serve(FaultPlan::default());
    assert!(m_ok.recal_swaps >= 1, "no baseline swap: {}", m_ok.report());

    // every check panics mid-application: the first check advances the
    // drift baseline then dies, so nothing is ever parked — no swap, no
    // half-applied qparams — while the cadence (and serving) run on
    let (imgs_p, m_p) =
        serve(FaultPlan { recal_panic_per_mille: 1000, ..FaultPlan::new(31) });
    assert_eq!(m_p.recal_swaps, 0, "a half-applied plan reached a round: {}", m_p.report());
    assert!(m_p.recal_checks >= 2, "a panicked check wedged the cadence: {}", m_p.report());
    assert!(m_p.faults_injected >= m_p.recal_checks, "{}", m_p.report());
    assert_eq!(imgs_p.len(), imgs_ok.len(), "panicked checks lost requests");
    for img in &imgs_p {
        assert!(img.iter().all(|b| f32::from_bits(*b).is_finite()));
    }

    // every check stalls first: decisions and bits must not move
    let (imgs_s, m_s) = serve(FaultPlan {
        recal_slow_per_mille: 1000,
        slow_ms: 1,
        ..FaultPlan::new(31)
    });
    assert_eq!(imgs_s, imgs_ok, "a slow check changed served bits");
    assert_eq!(m_s.recal_swaps, m_ok.recal_swaps, "a slow check changed swap decisions");
    assert!(m_s.faults_injected >= 1, "{}", m_s.report());
    std::env::remove_var("MSFP_RUNS");
}

#[test]
fn missing_artifacts_fail_cleanly() {
    let bad = std::env::temp_dir().join("msfp_no_artifacts");
    std::fs::create_dir_all(&bad).unwrap();
    match Pipeline::new(&bad, tiny_scale()) {
        Ok(_) => panic!("pipeline must not build without a manifest"),
        Err(err) => assert!(format!("{err:#}").contains("make artifacts")),
    }
}

#[test]
fn checkpoint_cache_reused() {
    let Some(dir) = artifacts() else { return };
    let runs = std::env::temp_dir().join("msfp_integ_cache");
    let _ = std::fs::remove_dir_all(&runs);
    std::env::set_var("MSFP_RUNS", &runs);
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p1 = pl.prepare(Corpus::CelebaSyn).unwrap();
    let p2 = pl.prepare(Corpus::CelebaSyn).unwrap(); // must hit the cache
    assert_eq!(p1.params, p2.params);
    std::env::remove_var("MSFP_RUNS");
}

/// The fleet headline invariant: 1-, 2- and 4-shard fleets over the same
/// deterministic workload + observation stream produce byte-identical
/// fleet-merged sketch windows, bit-identical drift scores, the same
/// broadcast recalibration plan (layers + swap epoch) and bit-identical
/// per-request images — and the merged window detects drift that no
/// single shard's slice could have been trusted with alone.
#[test]
fn fleet_serving_is_shard_count_invariant_and_merges_drift() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::{Fleet, FleetCfg, route};
    use msfp::quant::msfp::{LayerCalib, Method, QuantOpts, StateDir};
    use msfp::recal::RecalPlanner;

    std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_integ_fleet"));
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p = pl.prepare(Corpus::CifarSyn).unwrap();
    let info = p.info.clone();
    let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4)
        .with_io_8bit(&info.io_layer_indices());
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(p.params.clone());
    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;

    // the shared drift baseline every run scores against (build_session
    // is deterministic, so each run's fleet session carries exactly this)
    let calib: Vec<LayerCalib> =
        pl.build_session(&p).unwrap().calib().to_vec();
    let shift = 1.0f32;
    let feed_cap = 768usize; // samples fed per layer (chunks of 8)

    // replay the feed's routing (pure in the observation ids) to size the
    // planner's trust gate: `min_samples` must exceed every single
    // shard's slice of every layer in every tested fleet size, while at
    // least one layer's full (merged) count still clears it — that is
    // exactly the "merging improves detection" regime
    let mut max_slice = 0usize;
    for shards in [2usize, 4] {
        let mut id = 0u64;
        for c in &calib {
            let len = c.acts.len().min(feed_cap);
            let mut per = vec![0usize; shards];
            let mut off = 0usize;
            while off < len {
                let take = (len - off).min(8);
                per[route(id, 0, shards)] += take;
                id += 1;
                off += take;
            }
            id += 1; // the widen_layer id
            max_slice = max_slice.max(per.into_iter().max().unwrap());
        }
    }
    let min_samples = max_slice + 1;
    let full_max = (0..calib.len()).map(|l| calib[l].acts.len().min(feed_cap)).max().unwrap();
    assert!(
        full_max >= min_samples,
        "fixture cannot separate solo from merged: full {full_max} < gate {min_samples}"
    );
    let planner = RecalPlanner { min_samples, ..RecalPlanner::default() };

    let feed = |fleet: &Fleet| {
        let mut rng = Rng::new(18);
        let mut id = 0u64;
        for (l, c) in calib.iter().enumerate() {
            let acts: Vec<f32> = c.acts.iter().take(feed_cap).map(|v| v + shift).collect();
            for chunk in acts.chunks(8) {
                let t = rng.range(0.0, pl.sched.t_total as f32);
                fleet.observe(id, l, t, chunk);
                id += 1;
            }
            // exact extrema land on one routed shard; the canonical merge
            // widens with every input, so the fleet window carries them
            fleet.widen_layer(id, l, 0.0, c.min + shift, c.max + shift);
            id += 1;
        }
    };
    let workload = |lo: u64| -> Vec<Request> {
        (0..6u64)
            .map(|i| {
                let mut r = Request::new(0, 2, 6);
                r.seed = lo + i;
                r
            })
            .collect()
    };
    let collect = |rxs: Vec<msfp::coordinator::ResponseRx>| -> Vec<Vec<u32>> {
        rxs.into_iter()
            .map(|rx| {
                rx.recv().unwrap().unwrap_done().images.iter().map(|v| v.to_bits()).collect()
            })
            .collect()
    };

    let state_root = std::env::temp_dir().join("msfp_integ_fleet_state");
    let _ = std::fs::remove_dir_all(&state_root);
    std::fs::create_dir_all(&state_root).unwrap();
    let run = |shards: usize, state_dir: Option<&std::path::Path>| {
        let session = pl.build_session(&p).unwrap();
        let q = pl.quantize_with_session(&p, &session, &spec).unwrap();
        let mut cfg = FleetCfg::new(shards, q.state, session, opts.clone());
        cfg.seed = 21;
        cfg.workers = 1;
        cfg.planner = planner.clone();
        cfg.state_dir = state_dir.map(StateDir::new);
        let mut fleet = Fleet::spawn(
            Arc::clone(&den),
            info.clone(),
            pl.sched.clone(),
            Arc::clone(&params),
            cfg,
        );
        feed(&fleet);
        // in a multi-shard fleet no single shard's slice may be trusted
        // alone: the planner (same gate, same baseline) plans nothing on
        // any solo window — only the merged one crosses the gate below
        if shards > 1 {
            for s in 0..shards {
                let w = fleet.shard_window(s).lock().unwrap().clone();
                assert!(
                    planner.plan(&calib, &w).layers.is_empty(),
                    "{shards}-shard fleet: shard {s}'s slice was trusted alone"
                );
            }
        }
        let imgs1 = collect(fleet.submit_many(workload(60)).unwrap());
        let agg = fleet.aggregate().unwrap();
        let imgs2 = collect(fleet.submit_many(workload(80)).unwrap());
        (imgs1, agg, imgs2, fleet.shutdown())
    };

    let (one_1, agg_1, one_2, rep_1) = run(1, None);
    let (two_1, agg_2, two_2, rep_2) = run(2, Some(&state_root));
    let (four_1, agg_4, four_2, rep_4) = run(4, None);

    // the merged window is partition-invariant: byte-identical for every
    // shard count, with zero lossy positions and zero skipped shards
    for agg in [&agg_1, &agg_2, &agg_4] {
        assert_eq!(agg.epoch, 0);
        assert_eq!(agg.lossy_positions, 0, "shard windows overflowed the test's cap");
        assert_eq!(agg.skipped_windows, 0);
    }
    assert_eq!(agg_1.window.to_bytes(), agg_2.window.to_bytes(), "1 vs 2 shards");
    assert_eq!(agg_2.window.to_bytes(), agg_4.window.to_bytes(), "2 vs 4 shards");
    // ... so drift scores and the broadcast plan agree exactly
    assert_eq!(agg_1.scores, agg_2.scores);
    assert_eq!(agg_2.scores, agg_4.scores);
    let plan_layers = |a: &msfp::coordinator::FleetAggregate| -> Vec<(u32, u32)> {
        a.swap
            .as_ref()
            .expect("the merged window must cross the trust gate and plan a swap")
            .layers
            .iter()
            .map(|&(l, s)| (l, s.to_bits()))
            .collect()
    };
    assert_eq!(plan_layers(&agg_1), plan_layers(&agg_2));
    assert_eq!(plan_layers(&agg_2), plan_layers(&agg_4));
    for rep in [&rep_1, &rep_2, &rep_4] {
        assert_eq!(rep.snapshot.swap_epoch, Some(0), "fleet swap landed at epoch 0");
    }

    // every shard applied the broadcast exactly once, with a real
    // fingerprint transition in its audit trail
    for (n, rep) in [(1usize, &rep_1), (2, &rep_2), (4, &rep_4)] {
        assert_eq!(rep.merged.recal_swaps, n, "every shard must apply the fleet swap");
        assert_eq!(rep.merged.swap_audits.len(), n);
        assert!(rep.merged.swap_audits.iter().all(|a| a.old_fp != a.new_fp));
        assert_eq!(rep.per_shard.len(), n);
        let per: usize = rep.per_shard.iter().map(|m| m.images_done).sum();
        assert_eq!(per, rep.merged.images_done);
        assert_eq!(rep.merged.images_done, 24, "6 requests x 2 images x 2 epochs");
    }

    // per-request image bits are routing-invariant, both before and after
    // the fleet-wide hot-swap
    assert_eq!(one_1, two_1, "pre-swap images moved between 1 and 2 shards");
    assert_eq!(two_1, four_1, "pre-swap images moved between 2 and 4 shards");
    assert_eq!(one_2, two_2, "post-swap images moved between 1 and 2 shards");
    assert_eq!(two_2, four_2, "post-swap images moved between 2 and 4 shards");
    for img in one_2.iter().chain(&one_1) {
        assert!(img.iter().all(|b| f32::from_bits(*b).is_finite()));
    }

    // the fleet state dir got the full artifact set, and the persisted
    // snapshot is exactly the one the report carries
    let sd = StateDir::new(&state_root);
    assert!(sd.sketch_path().exists(), "merged window not persisted");
    assert!(sd.telemetry_path().exists(), "fleet metrics.jsonl not persisted");
    let json = std::fs::read_to_string(state_root.join("fleet.json")).unwrap();
    let parsed = msfp::obs::FleetSnapshot::from_json(
        &msfp::util::json::Json::parse(&json).unwrap(),
    )
    .unwrap();
    assert_eq!(parsed, rep_2.snapshot, "persisted fleet snapshot drifted from the report");
    let prom = std::fs::read_to_string(state_root.join("fleet.prom")).unwrap();
    assert!(prom.contains("msfp_fleet_shards 2"), "prometheus page lost the shard count");
    std::env::remove_var("MSFP_RUNS");
}

/// The aggregator's error path (the hardened `SketchSet::merge`): a shard
/// whose window comes back with a mismatched layout is skipped, warned
/// about and counted — aggregation proceeds on the shards that agree and
/// serving never dies.
#[test]
fn fleet_aggregation_skips_bad_shard_windows_instead_of_dying() {
    let Some(dir) = artifacts() else { return };
    use msfp::coordinator::{Fleet, FleetCfg};
    use msfp::quant::msfp::{Method, QuantOpts};
    use msfp::recal::SketchSet;

    std::env::set_var("MSFP_RUNS", std::env::temp_dir().join("msfp_integ_fleet_bad"));
    let pl = Pipeline::new(&dir, tiny_scale()).unwrap();
    let p = pl.prepare(Corpus::CifarSyn).unwrap();
    let info = p.info.clone();
    let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4)
        .with_io_8bit(&info.io_layer_indices());
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &info).unwrap());
    let params = Arc::new(p.params.clone());
    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;

    let session = pl.build_session(&p).unwrap();
    let q = pl.quantize_with_session(&p, &session, &spec).unwrap();
    let calib_feed: Vec<(usize, Vec<f32>)> = session
        .calib()
        .iter()
        .enumerate()
        .map(|(l, c)| (l, c.acts.iter().take(256).map(|v| v + 1.0).collect()))
        .collect();
    let mut cfg = FleetCfg::new(2, q.state, session, opts);
    cfg.seed = 21;
    cfg.workers = 1;
    let mut fleet = Fleet::spawn(
        Arc::clone(&den),
        info.clone(),
        pl.sched.clone(),
        Arc::clone(&params),
        cfg,
    );
    let mut rng = Rng::new(18);
    let mut id = 0u64;
    for (l, acts) in &calib_feed {
        for chunk in acts.chunks(8) {
            fleet.observe(id, *l, rng.range(0.0, pl.sched.t_total as f32), chunk);
            id += 1;
        }
    }

    // poison shard 1: its window comes back with a different layer count,
    // which the aggregator must reject per shard, not panic on (the old
    // `SketchSet::merge` assert would have taken the fleet down)
    *fleet.shard_window(1).lock().unwrap() =
        SketchSet::new(info.n_layers + 1, 4, 8, pl.sched.t_total, 3);
    let agg = fleet.aggregate().unwrap();
    assert_eq!(agg.skipped_windows, 1, "the bad shard must be counted, not fatal");
    assert_eq!(agg.window.n_layers(), info.n_layers, "merged layout follows the fleet's");
    assert_eq!(agg.scores.len(), info.n_layers);

    // the fleet still serves after the partial aggregation
    let mut req = Request::new(0, 2, 4);
    req.seed = 9;
    let rxs = fleet.submit_many(vec![req]).unwrap();
    let done = rxs.into_iter().next().unwrap().recv().unwrap().unwrap_done();
    assert!(done.images.iter().all(|v| v.is_finite()));
    let rep = fleet.shutdown();
    assert_eq!(rep.snapshot.skipped_windows, 1);
    assert_eq!(rep.merged.images_done, 2);
    std::env::remove_var("MSFP_RUNS");
}
