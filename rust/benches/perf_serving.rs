//! Perf bench (§Perf headline): per-eval latency by batch class (fp vs
//! quantized), and coordinator throughput with the sequential round
//! executor (workers=1, the pre-parallelism baseline) vs the parallel
//! round executor (workers=auto) on a multi-timestep workload — the shape
//! continuous batching actually produces (requests at different denoising
//! phases ⇒ several distinct-t batches per round, which only the parallel
//! executor can overlap).
//!
//! Emits machine-readable rows to BENCH_serving.json (path override:
//! BENCH_SERVING_JSON) via util::bench::write_json_rows:
//!   * `serve_eval_{fp,q}_b{B}` timing rows (per-eval latency by class);
//!   * `coordinator_sequential_exec` / `coordinator_parallel` img/s rows;
//!   * `selection_cache_hit_rate` + round exec/sched split metric rows.
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use msfp::coordinator::{self, Metrics, Request, ServeMode, ServerCfg};
use msfp::lora::hub::AllocStrategy;
use msfp::lora::Router;
use msfp::model::manifest::Manifest;
use msfp::model::ParamStore;
use msfp::pipeline::Pipeline;
use msfp::runtime::{Denoiser, Engine, QuantState};
use msfp::schedule::Schedule;
use msfp::util::bench::{bench_with_budget, metric_row, write_json_rows};
use msfp::util::json::Json;
use msfp::util::rng::Rng;

/// ≥ 8 concurrent requests at ≥ 2 distinct t per round: half the
/// requests run 6 denoising steps, half run 9, so every round packs (at
/// least) two distinct-t batches.
fn workload() -> Vec<Request> {
    (0..16u64)
        .map(|i| {
            let mut r = Request::new(0, 2, if i % 2 == 0 { 6 } else { 9 });
            r.seed = i;
            r
        })
        .collect()
}

fn serve_workload(
    den: &Arc<Denoiser>,
    info: &msfp::model::manifest::ModelInfo,
    sched: &Schedule,
    params: &Arc<Vec<f32>>,
    qs: &QuantState,
    workers: usize,
) -> (f64, Metrics) {
    let handle = coordinator::spawn(
        Arc::clone(den),
        info.clone(),
        sched.clone(),
        Arc::clone(params),
        ServerCfg {
            mode: ServeMode::Quant(qs.clone()),
            decode_latents: false,
            seed: 1,
            workers,
        },
    );
    let t0 = Instant::now();
    let rxs = handle.submit_many(workload()).unwrap();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = handle.shutdown();
    (m.images_done as f64 / wall, m)
}

fn main() {
    let dir = Pipeline::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP perf_serving: artifacts not built");
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    let info = m.model("ddim16").unwrap().clone();
    let engine = Arc::new(Engine::new(&dir).unwrap());
    let den = Arc::new(Denoiser::new(Arc::clone(&engine), &info).unwrap());
    let params = Arc::new(ParamStore::load_init(&info, &dir).unwrap().flat);
    let sched = Schedule::linear(100);
    let mut rng = Rng::new(5);
    let mut rows: Vec<Json> = Vec::new();

    // --- raw step latency by batch class (fp vs quantized) ----------------
    let mut qp = Vec::new();
    for _ in 0..info.n_layers {
        qp.extend_from_slice(&[1.0, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
    }
    let qs = QuantState {
        qparams: qp,
        lora: vec![0.0; info.lora_size],
        router: Router::init(&info, &mut rng),
        hub_mask: vec![1.0, 1.0, 0.0, 0.0],
        strategy: AllocStrategy::Learned,
        t_total: 100,
    };
    println!("\n-- per-eval latency by batch class (after warmup) --");
    for b in [1usize, 2, 4, 8] {
        let x = vec![0.2f32; info.x_size(b)];
        let cond = vec![0.0; b];
        let t = vec![5.0f32; b];
        // warmup (compile)
        den.eps_fp(&params, &x, &t, &cond).unwrap();
        den.eps_q(&params, &qs, &x, 5.0, &cond, &mut rng).unwrap();
        let fp = bench_with_budget(&format!("serve_eval_fp_b{b}"), Duration::from_secs(1), || {
            den.eps_fp(&params, &x, &t, &cond).unwrap();
        });
        let q = bench_with_budget(&format!("serve_eval_q_b{b}"), Duration::from_secs(1), || {
            den.eps_q(&params, &qs, &x, 5.0, &cond, &mut rng).unwrap();
        });
        println!(
            "  b={b}: fp {:8.2} ms/eval ({:6.1} img/s)   q {:8.2} ms/eval ({:6.1} img/s)   q/fp {:.2}x",
            fp.median_ns / 1e6,
            b as f64 / (fp.median_ns / 1e9),
            q.median_ns / 1e6,
            b as f64 / (q.median_ns / 1e9),
            q.median_ns / fp.median_ns
        );
        rows.push(fp.to_json());
        rows.push(q.to_json());
    }

    // --- coordinator throughput: sequential vs parallel round executor ----
    println!("\n-- coordinator throughput (16 requests x 2 images, 6/9 steps mixed, quantized) --");
    // warmup run so the executor comparison is not confounded by lazy
    // artifact compilation
    serve_workload(&den, &info, &sched, &params, &qs, 1);

    let (seq_thpt, seq_m) = serve_workload(&den, &info, &sched, &params, &qs, 1);
    println!("  sequential-exec (workers=1): {}", seq_m.report());
    let (par_thpt, par_m) = serve_workload(&den, &info, &sched, &params, &qs, 0);
    println!("  parallel-exec   (workers=auto): {}", par_m.report());
    println!(
        "  parallel/sequential throughput: {:.2}x  (sel-cache hit rate {:.0}%)",
        par_thpt / seq_thpt,
        par_m.sel_hit_rate() * 100.0
    );
    rows.push(metric_row("coordinator_sequential_exec", seq_thpt, "img/s"));
    rows.push(metric_row("coordinator_parallel", par_thpt, "img/s"));
    rows.push(metric_row("selection_cache_hit_rate", par_m.sel_hit_rate(), "ratio"));
    rows.push(metric_row(
        "coordinator_parallel_exec_fraction",
        par_m.exec_fraction(),
        "ratio",
    ));
    rows.push(metric_row(
        "coordinator_sequential_exec_fraction",
        seq_m.exec_fraction(),
        "ratio",
    ));

    let path =
        std::env::var("BENCH_SERVING_JSON").unwrap_or_else(|_| "BENCH_serving.json".to_string());
    match write_json_rows(Path::new(&path), rows) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
