//! Perf bench (§Perf headline): per-eval latency by batch class (fp vs
//! quantized), and coordinator throughput with the sequential round
//! executor (workers=1, the pre-parallelism baseline) vs the parallel
//! round executor (workers=auto) on a multi-timestep workload — the shape
//! continuous batching actually produces (requests at different denoising
//! phases ⇒ several distinct-t batches per round, which only the parallel
//! executor can overlap).
//!
//! Emits machine-readable rows to BENCH_serving.json (path override:
//! BENCH_SERVING_JSON) via util::bench::write_json_rows:
//!   * `serve_eval_{fp,q}_b{B}` timing rows (per-eval latency by class);
//!   * `coordinator_sequential_exec` / `coordinator_parallel` img/s rows;
//!   * `selection_cache_hit_rate` + round exec/sched split metric rows;
//!   * `trace_overhead` / `trace_overhead_ratio`: mean-round-latency delta
//!     of the parallel run (flight recorder + telemetry on by default) vs
//!     the same workload with `ObsCfg::off()` — the observability layer's
//!     scheduler cost, budgeted at < 2% of mean round time;
//!   * `hot_swap_stall`: mean-round-latency delta of a serve run whose
//!     background recalibration lands qparams hot-swaps vs the same run
//!     without recalibration (the cost of swap application + check
//!     scheduling as seen by the scheduler loop, NOT of the search itself,
//!     which runs on the pool);
//!   * `probe_overhead`: mean-round-latency delta with the shadow prober
//!     at budget 2 vs budget 0 (detector parked — the pure cost of
//!     self-calibration probing);
//!   * `restart_warm_vs_cold`: rounds until the first hot-swap for a
//!     cold server (empty sketch window, prober must refill it) vs a warm
//!     restart (window restored from the persisted state dir);
//!   * `ckpt_overhead`: mean-round-latency delta of the hot-swap run with
//!     state-dir persistence on (swap checkpoints written off-thread with
//!     capped retries) vs the same run without a state dir;
//!   * `reconfigure_stall`: mean-round-latency delta of the throughput
//!     workload with a burst of live `reconfigure` calls (no-op knobs)
//!     vs the plain parallel run — the cost of applying an SLO swap at a
//!     round boundary;
//!   * `overload_*`: the same workload oversubscribed against a queue
//!     budget with a two-rung degradation ladder installed — per-class
//!     queue-wait p50/p99 (rounds) plus shed / downgraded-round /
//!     step-cut / per-rung round counts;
//!   * `fleet_shards{N}_img_per_s`: the throughput workload through an
//!     N-shard fleet (consistent-hash router, N ∈ {1, 2, 4}) — the
//!     scaling story of running N coordinators behind one front door;
//!   * `fleet_merge_overhead`: wall time of one 4-shard aggregation
//!     boundary (round-boundary harvest of every shard + canonical
//!     window merge + one drift check/plan + swap broadcast).
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use msfp::coordinator::{
    self, degraded_state, Fleet, FleetCfg, LadderRung, Metrics, ObsCfg, Request, ServeMode,
    ServeRecal, ServerCfg, SloCfg, SloClass,
};
use msfp::lora::hub::AllocStrategy;
use msfp::lora::Router;
use msfp::model::manifest::Manifest;
use msfp::model::ParamStore;
use msfp::pipeline::Pipeline;
use msfp::quant::msfp::{LayerCalib, Method, QuantOpts};
use msfp::quant::QuantSession;
use msfp::recal::SketchSet;
use msfp::runtime::{Denoiser, Engine, QuantState};
use msfp::schedule::Schedule;
use msfp::util::bench::{bench_with_budget, metric_row, write_json_rows};
use msfp::util::json::Json;
use msfp::util::rng::Rng;

/// ≥ 8 concurrent requests at ≥ 2 distinct t per round: half the
/// requests run 6 denoising steps, half run 9, so every round packs (at
/// least) two distinct-t batches.
fn workload() -> Vec<Request> {
    (0..16u64)
        .map(|i| {
            let mut r = Request::new(0, 2, if i % 2 == 0 { 6 } else { 9 });
            r.seed = i;
            r
        })
        .collect()
}

fn serve_workload(
    den: &Arc<Denoiser>,
    info: &msfp::model::manifest::ModelInfo,
    sched: &Schedule,
    params: &Arc<Vec<f32>>,
    qs: &QuantState,
    workers: usize,
    recal: Option<ServeRecal>,
    probe_budget: usize,
) -> (f64, Metrics) {
    let handle = coordinator::spawn(
        Arc::clone(den),
        info.clone(),
        sched.clone(),
        Arc::clone(params),
        ServerCfg {
            seed: 1,
            workers,
            recal,
            probe_budget,
            ..ServerCfg::new(ServeMode::Quant(qs.clone()))
        },
    );
    let t0 = Instant::now();
    let rxs = handle.submit_many(workload()).unwrap();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = handle.shutdown();
    (m.images_done as f64 / wall, m)
}

/// Mean scheduler-observed round latency in ms (exec + sched over rounds).
fn mean_round_ms(m: &Metrics) -> f64 {
    if m.rounds == 0 {
        return 0.0;
    }
    (m.round_exec + m.round_sched).as_secs_f64() * 1e3 / m.rounds as f64
}

fn main() {
    let dir = Pipeline::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP perf_serving: artifacts not built");
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    let info = m.model("ddim16").unwrap().clone();
    let engine = Arc::new(Engine::new(&dir).unwrap());
    let den = Arc::new(Denoiser::new(Arc::clone(&engine), &info).unwrap());
    let params = Arc::new(ParamStore::load_init(&info, &dir).unwrap().flat);
    let sched = Schedule::linear(100);
    let mut rng = Rng::new(5);
    let mut rows: Vec<Json> = Vec::new();

    // --- raw step latency by batch class (fp vs quantized) ----------------
    let mut qp = Vec::new();
    for _ in 0..info.n_layers {
        qp.extend_from_slice(&[1.0, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
    }
    let qs = QuantState {
        qparams: qp,
        lora: vec![0.0; info.lora_size],
        router: Router::init(&info, &mut rng),
        hub_mask: vec![1.0, 1.0, 0.0, 0.0],
        strategy: AllocStrategy::Learned,
        t_total: 100,
    };
    println!("\n-- per-eval latency by batch class (after warmup) --");
    for b in [1usize, 2, 4, 8] {
        let x = vec![0.2f32; info.x_size(b)];
        let cond = vec![0.0; b];
        let t = vec![5.0f32; b];
        // warmup (compile)
        den.eps_fp(&params, &x, &t, &cond).unwrap();
        den.eps_q(&params, &qs, &x, 5.0, &cond, &mut rng).unwrap();
        let fp = bench_with_budget(&format!("serve_eval_fp_b{b}"), Duration::from_secs(1), || {
            den.eps_fp(&params, &x, &t, &cond).unwrap();
        });
        let q = bench_with_budget(&format!("serve_eval_q_b{b}"), Duration::from_secs(1), || {
            den.eps_q(&params, &qs, &x, 5.0, &cond, &mut rng).unwrap();
        });
        // packed backend: same quantization contract through the native
        // fused dequantize-matmul path (no graph, no batch-class padding)
        let sel = qs.selection(5.0, &mut rng);
        let mut scratch = msfp::runtime::EpsScratch::default();
        let mut pout = Vec::new();
        den.eps_q_packed_into(&params, &qs, &sel, &x, 5.0, &cond, &mut scratch, &mut pout)
            .unwrap(); // warmup: packs the model once
        let qp = bench_with_budget(
            &format!("serve_eval_q_packed_b{b}"),
            Duration::from_secs(1),
            || {
                den.eps_q_packed_into(
                    &params, &qs, &sel, &x, 5.0, &cond, &mut scratch, &mut pout,
                )
                .unwrap();
            },
        );
        println!(
            "  b={b}: fp {:8.2} ms/eval ({:6.1} img/s)   q {:8.2} ms/eval ({:6.1} img/s)   q/fp {:.2}x   q-packed {:8.2} ms/eval ({:.2}x of graph)",
            fp.median_ns / 1e6,
            b as f64 / (fp.median_ns / 1e9),
            q.median_ns / 1e6,
            b as f64 / (q.median_ns / 1e9),
            q.median_ns / fp.median_ns,
            qp.median_ns / 1e6,
            qp.median_ns / q.median_ns
        );
        rows.push(fp.to_json());
        rows.push(q.to_json());
        rows.push(qp.to_json());
    }
    println!("  (packed backend resident weights: {} B)", den.packed_bytes());

    // --- coordinator throughput: sequential vs parallel round executor ----
    println!("\n-- coordinator throughput (16 requests x 2 images, 6/9 steps mixed, quantized) --");
    // warmup run so the executor comparison is not confounded by lazy
    // artifact compilation
    serve_workload(&den, &info, &sched, &params, &qs, 1, None, 0);

    let (seq_thpt, seq_m) = serve_workload(&den, &info, &sched, &params, &qs, 1, None, 0);
    println!("  sequential-exec (workers=1): {}", seq_m.report());
    let (par_thpt, par_m) = serve_workload(&den, &info, &sched, &params, &qs, 0, None, 0);
    println!("  parallel-exec   (workers=auto): {}", par_m.report());
    println!(
        "  parallel/sequential throughput: {:.2}x  (sel-cache hit rate {:.0}%)",
        par_thpt / seq_thpt,
        par_m.sel_hit_rate() * 100.0
    );
    rows.push(metric_row("coordinator_sequential_exec", seq_thpt, "img/s"));
    rows.push(metric_row("coordinator_parallel", par_thpt, "img/s"));
    rows.push(metric_row("selection_cache_hit_rate", par_m.sel_hit_rate(), "ratio"));
    rows.push(metric_row(
        "coordinator_parallel_exec_fraction",
        par_m.exec_fraction(),
        "ratio",
    ));
    rows.push(metric_row(
        "coordinator_sequential_exec_fraction",
        seq_m.exec_fraction(),
        "ratio",
    ));

    // --- trace overhead: flight recorder + telemetry on vs off ------------
    // The default config records every scheduling decision into the
    // bounded event ring and pushes one telemetry sample per round; the
    // parallel run above is that recorder-on configuration. The same
    // workload with `ObsCfg::off()` measures what the observability layer
    // costs the scheduler loop — budgeted at < 2% of mean round time.
    println!("\n-- trace overhead (flight recorder + telemetry on vs off) --");
    let handle = coordinator::spawn(
        Arc::clone(&den),
        info.clone(),
        sched.clone(),
        Arc::clone(&params),
        ServerCfg {
            seed: 1,
            workers: 0,
            obs: ObsCfg::off(),
            ..ServerCfg::new(ServeMode::Quant(qs.clone()))
        },
    );
    let rxs = handle.submit_many(workload()).unwrap();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let off_m = handle.shutdown();
    let trace_overhead = mean_round_ms(&par_m) - mean_round_ms(&off_m);
    let trace_ratio =
        if mean_round_ms(&off_m) > 0.0 { trace_overhead / mean_round_ms(&off_m) } else { 0.0 };
    println!(
        "  mean round {:.3} ms (recorder on, {} events) vs {:.3} ms (off) -> overhead {:+.3} ms ({:+.2}%)",
        mean_round_ms(&par_m),
        par_m.trace_events,
        mean_round_ms(&off_m),
        trace_overhead,
        trace_ratio * 100.0
    );
    if trace_ratio > 0.02 {
        println!("  WARNING: trace overhead above the 2% budget");
    }
    rows.push(metric_row("coordinator_round_ms_trace_off", mean_round_ms(&off_m), "ms"));
    rows.push(metric_row("trace_overhead", trace_overhead, "ms"));
    rows.push(metric_row("trace_overhead_ratio", trace_ratio, "ratio"));

    // --- hot-swap stall: round latency with a recal swap landing ----------
    // The recal session runs over the real layer weights with a synthetic
    // calibration; its sketches replay that calibration *shifted*, so the
    // first background check flags every layer and a hot-swap lands while
    // the workload is in flight. The stall metric compares the scheduler's
    // mean round latency against the no-recal parallel run above.
    println!("\n-- hot-swap stall (same workload, background recal swap mid-serve) --");
    let calib: Vec<LayerCalib> = (0..info.n_layers)
        .map(|l| {
            let a: Vec<f32> = (0..1024)
                .map(|_| {
                    let v = rng.normal() * 2.0;
                    if l % 2 == 0 { v / (1.0 + (-v).exp()) } else { v }
                })
                .collect();
            LayerCalib::from_samples(format!("serve_l{l}"), a, l % 2 == 0)
        })
        .collect();
    let swap_recal = || -> ServeRecal {
        let weights = ParamStore::from_vec(&info, (*params).clone())
            .unwrap()
            .layer_weights(&info)
            .unwrap();
        let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4);
        let session = QuantSession::from_owned(weights, calib.clone());
        let _ = session.quantize(&opts); // warm: the job pays only the drifted layers
        let sketches =
            Arc::new(Mutex::new(SketchSet::new(info.n_layers, 4, 256, sched.t_total, 3)));
        {
            let mut set = sketches.lock().unwrap();
            let mut feed = Rng::new(9);
            for (l, c) in calib.iter().enumerate() {
                for chunk in c.acts.chunks(128) {
                    let t = feed.range(0.0, sched.t_total as f32);
                    let vals: Vec<f32> = chunk.iter().map(|v| v + 0.8).collect();
                    set.observe(l, t, &vals);
                }
                set.widen_layer(l, 0.0, c.min + 0.8, c.max + 0.8);
            }
        }
        let mut r = ServeRecal::new(session, opts, sketches);
        r.every_rounds = 2;
        r
    };
    let (_swap_thpt, swap_m) =
        serve_workload(&den, &info, &sched, &params, &qs, 0, Some(swap_recal()), 0);
    println!("  with-recal (workers=auto): {}", swap_m.report());
    let stall = mean_round_ms(&swap_m) - mean_round_ms(&par_m);
    println!(
        "  mean round {:.3} ms vs {:.3} ms without recal -> stall {:+.3} ms ({} swap(s), {} layer(s))",
        mean_round_ms(&swap_m),
        mean_round_ms(&par_m),
        stall,
        swap_m.recal_swaps,
        swap_m.recal_layers
    );
    if swap_m.recal_swaps == 0 {
        println!("  WARNING: no swap landed during the workload; stall row reflects checks only");
    }
    rows.push(metric_row("coordinator_round_ms_no_recal", mean_round_ms(&par_m), "ms"));
    rows.push(metric_row("coordinator_round_ms_recal_swap", mean_round_ms(&swap_m), "ms"));
    rows.push(metric_row("hot_swap_stall", stall, "ms"));
    rows.push(metric_row("hot_swap_count", swap_m.recal_swaps as f64, "swaps"));

    // --- checkpoint overhead: swap checkpoints to a state dir -------------
    // The same hot-swap workload with state-dir persistence on: every swap
    // checkpoints the quant state + sketch window off the scheduler thread
    // (capped-retry atomic writes). The delta vs the no-state-dir swap run
    // is the scheduler-observed cost of crash consistency.
    println!("\n-- checkpoint overhead (same swap workload, state-dir persistence on) --");
    let ckpt_root = std::env::temp_dir().join("msfp_bench_serving_ckpt");
    let _ = std::fs::remove_dir_all(&ckpt_root);
    let ckpt_sd = msfp::quant::msfp::StateDir::new(&ckpt_root);
    let (_, ckpt_m) = serve_workload(
        &den,
        &info,
        &sched,
        &params,
        &qs,
        0,
        Some(swap_recal().with_state_dir(ckpt_sd)),
        0,
    );
    let ckpt_overhead = mean_round_ms(&ckpt_m) - mean_round_ms(&swap_m);
    println!(
        "  mean round {:.3} ms vs {:.3} ms without persistence -> ckpt overhead {:+.3} ms ({} swap(s), {} ckpt fail(s)/{} retry(ies))",
        mean_round_ms(&ckpt_m),
        mean_round_ms(&swap_m),
        ckpt_overhead,
        ckpt_m.recal_swaps,
        ckpt_m.ckpt_fails,
        ckpt_m.ckpt_retries
    );
    rows.push(metric_row("coordinator_round_ms_ckpt", mean_round_ms(&ckpt_m), "ms"));
    rows.push(metric_row("ckpt_overhead", ckpt_overhead, "ms"));

    // --- reconfigure stall: live SLO swaps mid-serve ----------------------
    // The throughput workload with a burst of `reconfigure` calls carrying
    // no-op knobs (no budget, no ladder): serving behavior is unchanged,
    // so the round-latency delta vs the plain parallel run is the pure
    // cost of draining + applying SLO swaps at round boundaries.
    println!("\n-- reconfigure stall (live SLO swaps mid-serve, no-op knobs) --");
    let handle = coordinator::spawn(
        Arc::clone(&den),
        info.clone(),
        sched.clone(),
        Arc::clone(&params),
        ServerCfg { seed: 1, workers: 0, ..ServerCfg::new(ServeMode::Quant(qs.clone())) },
    );
    let rxs = handle.submit_many(workload()).unwrap();
    for _ in 0..8 {
        handle.reconfigure(SloCfg::default()).unwrap();
    }
    for rx in rxs {
        rx.recv().unwrap();
    }
    let recfg_m = handle.shutdown();
    let recfg_stall = mean_round_ms(&recfg_m) - mean_round_ms(&par_m);
    println!(
        "  mean round {:.3} ms vs {:.3} ms without reconfigures -> stall {:+.3} ms ({} applied)",
        mean_round_ms(&recfg_m),
        mean_round_ms(&par_m),
        recfg_stall,
        recfg_m.reconfigures
    );
    rows.push(metric_row("coordinator_round_ms_reconfigure", mean_round_ms(&recfg_m), "ms"));
    rows.push(metric_row("reconfigure_stall", recfg_stall, "ms"));
    rows.push(metric_row("reconfigure_count", recfg_m.reconfigures as f64, "swaps"));

    // --- probe overhead: shadow prober on vs off, detector parked ---------
    // Same workload and recal config with an astronomical drift threshold,
    // so the only difference between the two runs is the budgeted
    // calib_forward probes riding the worker pool. The delta is the
    // scheduler-observed cost of self-calibration (probe snapshot + pool
    // contention), not of recalibration itself.
    println!("\n-- probe overhead (shadow prober, budget 0 vs 2, no swaps) --");
    let probe_recal = |threshold: f32, min_samples: usize, every: usize| -> ServeRecal {
        let weights = ParamStore::from_vec(&info, (*params).clone())
            .unwrap()
            .layer_weights(&info)
            .unwrap();
        let session = QuantSession::from_owned(weights, calib.clone());
        let _ = session.quantize(&QuantOpts::new(Method::Msfp, info.n_layers, 4, 4));
        let sketches =
            Arc::new(Mutex::new(SketchSet::new(info.n_layers, 4, 256, sched.t_total, 3)));
        let mut r = ServeRecal::new(
            session,
            QuantOpts::new(Method::Msfp, info.n_layers, 4, 4),
            sketches,
        );
        r.planner = msfp::recal::RecalPlanner {
            threshold,
            min_samples,
            ..Default::default()
        };
        r.every_rounds = every;
        r
    };
    let (_, p0_m) = serve_workload(
        &den, &info, &sched, &params, &qs, 0, Some(probe_recal(f32::MAX, 64, 10_000)), 0,
    );
    let (_, p2_m) = serve_workload(
        &den, &info, &sched, &params, &qs, 0, Some(probe_recal(f32::MAX, 64, 10_000)), 2,
    );
    let probe_overhead = mean_round_ms(&p2_m) - mean_round_ms(&p0_m);
    println!(
        "  mean round {:.3} ms (budget 2, {} probes) vs {:.3} ms (budget 0) -> overhead {:+.3} ms",
        mean_round_ms(&p2_m),
        p2_m.probes,
        mean_round_ms(&p0_m),
        probe_overhead
    );
    rows.push(metric_row("coordinator_round_ms_probe0", mean_round_ms(&p0_m), "ms"));
    rows.push(metric_row("coordinator_round_ms_probe2", mean_round_ms(&p2_m), "ms"));
    rows.push(metric_row("probe_overhead", probe_overhead, "ms"));
    rows.push(metric_row("probe_count", p2_m.probes as f64, "probes"));

    // --- restart warm vs cold: rounds until the first hot-swap ------------
    // Cold: an empty window — the prober must accumulate min_samples from
    // live traffic (which drifts hard against the synthetic calibration
    // baseline) before the detector can swap. Warm: a restarted server
    // restores the persisted window from the cold run's state dir and
    // swaps at the first check. The row pair is the restart-blindness the
    // persistence satellite removes.
    println!("\n-- restart drift detection: cold (empty window) vs warm (restored) --");
    let state_root = std::env::temp_dir().join("msfp_bench_serving_state");
    let _ = std::fs::remove_dir_all(&state_root);
    let sd = msfp::quant::msfp::StateDir::new(&state_root);
    let min_samples = 4 * info.act_samples; // ≈ 2 budget-2 probe rounds/layer
    let cold_recal = probe_recal(0.08, min_samples, 1).with_state_dir(sd.clone());
    let (_, cold_m) = serve_workload(&den, &info, &sched, &params, &qs, 0, Some(cold_recal), 2);
    let warm_recal = probe_recal(0.08, min_samples, 1).with_state_dir(sd.clone());
    let (_, warm_m) = serve_workload(&den, &info, &sched, &params, &qs, 0, Some(warm_recal), 2);
    let to_f = |m: &Metrics| m.first_swap_round.map(|r| r as f64).unwrap_or(-1.0);
    println!(
        "  cold: first swap at round {:?} ({} probes)   warm: first swap at round {:?}",
        cold_m.first_swap_round, cold_m.probes, warm_m.first_swap_round
    );
    rows.push(metric_row("restart_cold_rounds_to_swap", to_f(&cold_m), "rounds"));
    rows.push(metric_row("restart_warm_rounds_to_swap", to_f(&warm_m), "rounds"));
    // the delta row only makes sense when both runs actually swapped; the
    // absolute rows above carry the -1 "never swapped" sentinel on their own
    match (cold_m.first_swap_round, warm_m.first_swap_round) {
        (Some(c), Some(w)) => {
            rows.push(metric_row("restart_warm_vs_cold", c as f64 - w as f64, "rounds"));
        }
        _ => println!("  WARNING: a run never swapped; restart_warm_vs_cold row omitted"),
    }

    // --- overload: admission control + graceful degradation ---------------
    // The throughput workload oversubscribed 6x against a queue budget of
    // 8 samples/round, classes cycling, with a two-rung coarser-qparams
    // degradation ladder installed and one best-effort request on an
    // impossible deadline. The rows are the SLO story under pressure: how
    // long each class queued, what was shed, and how much interactive
    // work rode each ladder rung.
    println!("\n-- overload (queue budget 8, two-rung ladder, mixed SLO classes) --");
    let mut deg_qp = qs.qparams.clone();
    for v in deg_qp.iter_mut().step_by(2) {
        *v *= 0.5;
    }
    let mut deg_qp2 = qs.qparams.clone();
    for v in deg_qp2.iter_mut().step_by(2) {
        *v *= 0.25;
    }
    let over_workload = || -> Vec<Request> {
        let mut v: Vec<Request> = (0..24u64)
            .map(|i| {
                let mut r = Request::new(i, 2, if i % 2 == 0 { 6 } else { 9 }).with_slo(
                    match i % 3 {
                        0 => SloClass::Interactive,
                        1 => SloClass::Batch,
                        _ => SloClass::BestEffort,
                    },
                );
                r.seed = i;
                r
            })
            .collect();
        let mut doomed = Request::new(99, 6, 9).with_slo(SloClass::BestEffort);
        doomed.deadline_rounds = 2;
        doomed.seed = 99;
        v.push(doomed);
        v
    };
    let handle = coordinator::spawn(
        Arc::clone(&den),
        info.clone(),
        sched.clone(),
        Arc::clone(&params),
        ServerCfg {
            seed: 1,
            workers: 0,
            slo: SloCfg {
                queue_budget: 8,
                step_cut: 2,
                ladder: vec![
                    LadderRung { wbits: 3, abits: 4, state: degraded_state(&qs, deg_qp) },
                    LadderRung { wbits: 2, abits: 4, state: degraded_state(&qs, deg_qp2) },
                ],
            },
            ..ServerCfg::new(ServeMode::Quant(qs.clone()))
        },
    );
    let rxs = handle.submit_many(over_workload()).unwrap();
    for rx in rxs {
        let _ = rx.recv().unwrap();
    }
    let over_m = handle.shutdown();
    println!("  {}", over_m.report());
    for class in SloClass::ALL {
        let name = format!("{class:?}").to_lowercase();
        let (p50, p99) = (over_m.queue_wait_p(class, 0.5), over_m.queue_wait_p(class, 0.99));
        println!("  {class:?}: queue wait p50/p99 = {p50}/{p99} rounds");
        rows.push(metric_row(&format!("overload_wait_p50_{name}"), p50 as f64, "rounds"));
        rows.push(metric_row(&format!("overload_wait_p99_{name}"), p99 as f64, "rounds"));
    }
    rows.push(metric_row("overload_shed", over_m.shed_total() as f64, "requests"));
    rows.push(metric_row(
        "overload_downgraded_rounds",
        over_m.downgraded_rounds as f64,
        "rounds",
    ));
    rows.push(metric_row("overload_step_cuts", over_m.downgraded_steps as f64, "steps"));
    for (i, &r) in over_m.rung_rounds.iter().enumerate() {
        rows.push(metric_row(&format!("overload_rung{i}_rounds"), r as f64, "rounds"));
    }

    // --- fleet serving: shard-count scaling + aggregation overhead --------
    // The throughput workload through a 1/2/4-shard fleet: requests route
    // by consistent hash over fleet-assigned ids, every shard serves the
    // same quantized state. Each shard's window carries a routed slice of
    // the same shifted calibration replay the hot-swap bench uses, so the
    // timed aggregation boundary does real work: harvest every shard,
    // canonically merge the windows, run one drift check on the merged
    // window and broadcast the resulting swap.
    println!("\n-- fleet serving (consistent-hash router, canonical window merge) --");
    let fleet_opts = || QuantOpts::new(Method::Msfp, info.n_layers, 4, 4);
    let mut merge_overhead_ms = None;
    for n in [1usize, 2, 4] {
        let weights = ParamStore::from_vec(&info, (*params).clone())
            .unwrap()
            .layer_weights(&info)
            .unwrap();
        let session = QuantSession::from_owned(weights, calib.clone());
        let _ = session.quantize(&fleet_opts()); // warm: swaps pay only drifted layers
        let mut cfg = FleetCfg::new(n, qs.clone(), session, fleet_opts());
        cfg.seed = 1;
        cfg.sketch_cap = 2048; // lossless shard windows: the canonical-merge regime
        let mut fleet = Fleet::spawn(
            Arc::clone(&den),
            info.clone(),
            sched.clone(),
            Arc::clone(&params),
            cfg,
        );
        let mut feed = Rng::new(9);
        let mut id = 0u64;
        for (l, c) in calib.iter().enumerate() {
            for chunk in c.acts.chunks(128) {
                let t = feed.range(0.0, sched.t_total as f32);
                let vals: Vec<f32> = chunk.iter().map(|v| v + 0.8).collect();
                fleet.observe(id, l, t, &vals);
                id += 1;
            }
            fleet.widen_layer(id, l, 0.0, c.min + 0.8, c.max + 0.8);
            id += 1;
        }
        let t0 = Instant::now();
        let rxs = fleet.submit_many(workload()).unwrap();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let agg = fleet.aggregate().unwrap();
        let agg_ms = t1.elapsed().as_secs_f64() * 1e3;
        let rep = fleet.shutdown();
        let thpt = rep.merged.images_done as f64 / wall;
        println!(
            "  shards={n}: {thpt:6.1} img/s   aggregate {agg_ms:7.3} ms ({} swap layer(s), {} lossy position(s))",
            agg.swap.as_ref().map(|s| s.layers.len()).unwrap_or(0),
            agg.lossy_positions
        );
        rows.push(metric_row(&format!("fleet_shards{n}_img_per_s"), thpt, "img/s"));
        if n == 4 {
            merge_overhead_ms = Some(agg_ms);
        }
    }
    if let Some(ms) = merge_overhead_ms {
        rows.push(metric_row("fleet_merge_overhead", ms, "ms"));
    }

    let path =
        std::env::var("BENCH_SERVING_JSON").unwrap_or_else(|_| "BENCH_serving.json".to_string());
    match write_json_rows(Path::new(&path), rows) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
