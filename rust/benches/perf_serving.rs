//! Perf bench (§Perf headline): end-to-end serving throughput/latency by
//! batch size, quantized-vs-FP step latency, and coordinator overhead.
use std::sync::Arc;
use std::time::Instant;

use msfp::coordinator::{self, Request, ServeMode, ServerCfg};
use msfp::lora::hub::AllocStrategy;
use msfp::lora::Router;
use msfp::model::manifest::Manifest;
use msfp::model::ParamStore;
use msfp::pipeline::Pipeline;
use msfp::runtime::{Denoiser, Engine, QuantState};
use msfp::schedule::Schedule;
use msfp::util::rng::Rng;

fn main() {
    let dir = Pipeline::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP perf_serving: artifacts not built");
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    let info = m.model("ddim16").unwrap().clone();
    let engine = Arc::new(Engine::new(&dir).unwrap());
    let den = Arc::new(Denoiser::new(Arc::clone(&engine), &info).unwrap());
    let params = Arc::new(ParamStore::load_init(&info, &dir).unwrap().flat);
    let sched = Schedule::linear(100);
    let mut rng = Rng::new(5);

    // --- raw step latency by batch class (fp vs quantized) ----------------
    let mut qp = Vec::new();
    for _ in 0..info.n_layers {
        qp.extend_from_slice(&[1.0, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
    }
    let qs = QuantState {
        qparams: qp,
        lora: vec![0.0; info.lora_size],
        router: Router::init(&info, &mut rng),
        hub_mask: vec![1.0, 1.0, 0.0, 0.0],
        strategy: AllocStrategy::Learned,
        t_total: 100,
    };
    println!("\n-- per-eval latency by batch class (after warmup) --");
    for b in [1usize, 2, 4, 8] {
        let x = vec![0.2f32; info.x_size(b)];
        let cond = vec![0.0; b];
        let t = vec![5.0f32; b];
        // warmup (compile)
        den.eps_fp(&params, &x, &t, &cond).unwrap();
        den.eps_q(&params, &qs, &x, 5.0, &cond, &mut rng).unwrap();
        let n = 10;
        let t0 = Instant::now();
        for _ in 0..n {
            den.eps_fp(&params, &x, &t, &cond).unwrap();
        }
        let fp_ms = t0.elapsed().as_secs_f64() * 1e3 / n as f64;
        let t0 = Instant::now();
        for _ in 0..n {
            den.eps_q(&params, &qs, &x, 5.0, &cond, &mut rng).unwrap();
        }
        let q_ms = t0.elapsed().as_secs_f64() * 1e3 / n as f64;
        println!(
            "  b={b}: fp {fp_ms:8.2} ms/eval ({:6.1} img/s)   q {q_ms:8.2} ms/eval ({:6.1} img/s)   q/fp {:.2}x",
            b as f64 / (fp_ms / 1e3),
            b as f64 / (q_ms / 1e3),
            q_ms / fp_ms
        );
    }

    // --- serving throughput: sequential vs batched coordinator -------------
    println!("\n-- coordinator throughput (16 requests x 2 images x 6 steps, quantized) --");
    {
        let label = "batched";
        let handle = coordinator::spawn(
            Arc::clone(&den),
            info.clone(),
            sched.clone(),
            Arc::clone(&params),
            ServerCfg { mode: ServeMode::Quant(qs.clone()), decode_latents: false, seed: 1 },
        );
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                let mut r = Request::new(0, 2, 6);
                r.seed = i;
                handle.submit(r)
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = handle.shutdown();
        println!("  {label}: {} ({wall:.2}s wall)", m.report());
    }

    // sequential baseline: one request at a time
    let handle = coordinator::spawn(
        Arc::clone(&den),
        info.clone(),
        sched.clone(),
        Arc::clone(&params),
        ServerCfg { mode: ServeMode::Quant(qs.clone()), decode_latents: false, seed: 1 },
    );
    let t0 = Instant::now();
    for i in 0..16 {
        let mut r = Request::new(0, 2, 6);
        r.seed = i;
        handle.submit(r).recv().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = handle.shutdown();
    println!("  sequential: {} ({wall:.2}s wall)", m.report());
}
