//! Perf bench (L3 hot paths, §Perf): fake-qdq throughput, MSFP search cost
//! per layer and per model (grid-segment engine vs the retained scalar
//! oracle), batcher planning cost. Emits BENCH_quant.json (override the
//! path with the BENCH_JSON env var) so the perf trajectory is
//! machine-readable across PRs; `scripts/bench.sh` wraps the invocation.

use std::path::Path;
use std::time::Duration;

use msfp::coordinator::batcher::{plan, plan_mode, PlanMode, Ticket};
use msfp::linalg::tensor::Mat;
use msfp::quant::format::FpFormat;
use msfp::quant::fp::{fp_qdq_signed, fp_qdq_unsigned};
use msfp::quant::msfp::{quantize_model, LayerCalib, Method, QuantOpts};
use msfp::quant::packed::PackedMat;
use msfp::quant::search::{scalar, search_act_msfp, search_weight_fp, Quantizer};
use msfp::quant::QuantSession;
use msfp::util::bench::{bench_with_budget, black_box, metric_row, write_json_rows};
use msfp::util::json::Json;
use msfp::util::rng::Rng;
use msfp::util::threadpool::resolve_threads;

fn main() {
    let mut results = Vec::new();
    let mut rng = Rng::new(1);
    let xs: Vec<f32> = (0..65536).map(|_| rng.normal() * 2.0).collect();

    results.push(bench_with_budget("qdq_signed_64k_elems", Duration::from_secs(1), || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += fp_qdq_signed(x, 2.5, 2, 1);
        }
        black_box(acc);
    }));
    results.push(bench_with_budget("qdq_unsigned_zp_64k_elems", Duration::from_secs(1), || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += fp_qdq_unsigned(x, 2.5, 2, 2, -0.25);
        }
        black_box(acc);
    }));

    let acts: Vec<f32> = (0..4096).map(|_| {
        let v = rng.normal() * 2.0;
        v / (1.0 + (-v).exp())
    }).collect();
    let maxval0 = acts.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    results.push(bench_with_budget("msfp_act_search_1layer_4bit", Duration::from_secs(2), || {
        black_box(search_act_msfp(&acts, 4, maxval0, true, 100));
    }));
    // O(C·N) per-element oracle — the before/after-comparable baseline for
    // the grid-segment engine (quant::grid); must select the same argmin.
    results.push(bench_with_budget(
        "msfp_act_search_1layer_4bit_scalar",
        Duration::from_secs(2),
        || {
            black_box(scalar::search_act_msfp(&acts, 4, maxval0, true, 100));
        },
    ));
    let w: Vec<f32> = (0..9216).map(|_| rng.normal() * 0.1).collect();
    results.push(bench_with_budget("weight_search_1layer_4bit", Duration::from_secs(2), || {
        black_box(search_weight_fp(&w, 4, None, 40));
    }));

    // whole-model search (25 layers, per-layer × per-candidate parallel)
    let mut weights = Vec::new();
    let mut calib = Vec::new();
    for l in 0..25 {
        weights.push(rng.normal_vec(4096, 0.1));
        let a: Vec<f32> = (0..2048)
            .map(|_| {
                let v = rng.normal() * 2.0;
                if l % 2 == 0 { v / (1.0 + (-v).exp()) } else { v }
            })
            .collect();
        let min = a.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = a.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        calib.push(LayerCalib { name: format!("l{l}"), acts: a, min, max, aal_hint: l % 2 == 0 });
    }
    let opts = QuantOpts::new(Method::Msfp, 25, 4, 4);
    results.push(bench_with_budget("msfp_full_model_search_25layers", Duration::from_secs(5), || {
        black_box(quantize_model(&weights, &calib, &opts));
    }));

    // Table-5-style weight-space sweep (7 points, W6/A8 like exp::tables::
    // table5): "cold" rebuilds the per-tensor engines and re-runs every
    // sub-search at each point; "session" builds one QuantSession, shares
    // the sort/prefix preprocessing, and memoizes the weight-space-
    // invariant activation searches across points.
    let mut t5_weights = Vec::new();
    let mut t5_calib = Vec::new();
    for l in 0..8 {
        t5_weights.push(rng.normal_vec(4096, 0.1));
        let a: Vec<f32> = (0..2048)
            .map(|_| {
                let v = rng.normal() * 2.0;
                if l % 2 == 0 { v / (1.0 + (-v).exp()) } else { v }
            })
            .collect();
        let min = a.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = a.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        t5_calib.push(LayerCalib { name: format!("t5l{l}"), acts: a, min, max, aal_hint: l % 2 == 0 });
    }
    let t5_spaces =
        [(0.0001f32, 1.0f32), (0.0001, 2.0), (0.6, 2.0), (0.7, 2.0), (0.8, 2.0), (0.9, 2.0), (1.0, 2.0)];
    let t5_opts: Vec<QuantOpts> = t5_spaces
        .iter()
        .map(|&space| {
            let mut o = QuantOpts::new(Method::Msfp, 8, 6, 8);
            o.weight_space = Some(space);
            o
        })
        .collect();
    results.push(bench_with_budget("msfp_table5_sweep_cold", Duration::from_secs(6), || {
        for o in &t5_opts {
            black_box(quantize_model(&t5_weights, &t5_calib, o));
        }
    }));
    results.push(bench_with_budget("msfp_table5_sweep_session", Duration::from_secs(6), || {
        let session = QuantSession::new(&t5_weights, &t5_calib);
        for o in &t5_opts {
            black_box(session.quantize(o));
        }
    }));

    // Online-recalibration cost (the incremental-rebuild headline): after a
    // drift check flags ONE layer of a 12-layer model, `recal_one_layer`
    // applies update_layer_calib + re-quantize on the warm session (one
    // activation engine rebuilt, one layer's searches re-scored, eleven
    // layers replayed from memo) vs `rebuild_full_session`, the cold path a
    // session-less consumer pays (every engine re-sorted, every search
    // re-run). The acceptance gate: recal_one_layer must beat
    // rebuild_full_session.
    let mut rc_weights = Vec::new();
    let mut rc_calib = Vec::new();
    for l in 0..12 {
        rc_weights.push(rng.normal_vec(4096, 0.1));
        let a: Vec<f32> = (0..2048)
            .map(|_| {
                let v = rng.normal() * 2.0;
                if l % 2 == 0 { v / (1.0 + (-v).exp()) } else { v }
            })
            .collect();
        rc_calib.push(LayerCalib::from_samples(format!("rc{l}"), a, l % 2 == 0));
    }
    let rc_opts = QuantOpts::new(Method::Msfp, 12, 4, 4);
    let drifted: Vec<f32> = rc_calib[5].acts.iter().map(|v| v * 1.3 + 0.4).collect();
    let drifted = LayerCalib::from_samples("rc5", drifted, rc_calib[5].aal_hint);
    let mut rc_updated = rc_calib.clone();
    rc_updated[5] = drifted.clone();

    let mut warm = QuantSession::new(&rc_weights, &rc_calib);
    black_box(warm.quantize(&rc_opts)); // build engines + memos once
    results.push(bench_with_budget("recal_one_layer", Duration::from_secs(4), || {
        warm.update_layer_calib(5, drifted.clone());
        black_box(warm.quantize(&rc_opts));
    }));
    results.push(bench_with_budget("rebuild_full_session", Duration::from_secs(6), || {
        black_box(QuantSession::new(&rc_weights, &rc_updated).quantize(&rc_opts));
    }));

    // batcher planning
    let tickets: Vec<Ticket> = (0..64)
        .map(|i| Ticket { req: i, t: (i % 7) as f32, n: 1 + i % 5 })
        .collect();
    results.push(bench_with_budget("batcher_plan_64_tickets", Duration::from_secs(1), || {
        black_box(plan(&tickets, &[1, 2, 4, 8]));
    }));
    results.push(bench_with_budget(
        "batcher_plan_mixed_t_64_tickets",
        Duration::from_secs(1),
        || {
            black_box(plan_mode(&tickets, &[1, 2, 4, 8], PlanMode::MixedT));
        },
    ));

    // --- packed 4-bit storage + fused dequantize-matmul -------------------
    // A realistic W4 conv layer (3x3 kernel, 64 -> 64 channels, HWIO-flat
    // [fan_out=64, fan_in=576] after transpose): nibble-packed bytes vs the
    // f32 tensor, and the fused code-table-gather matmul vs the dense f32
    // `Mat::matmul` the graph-free baseline would pay after dequantizing.
    let mut rows: Vec<Json> = results.iter().map(|r| r.to_json()).collect();
    let (rows_n, cols_n, b_cols) = (64usize, 3 * 3 * 64, 128usize);
    let pw: Vec<f32> = (0..rows_n * cols_n).map(|_| rng.normal() * 0.1).collect();
    let pq = Quantizer::SignedFp { fmt: FpFormat::new(2, 1), maxval: 0.35 };
    let pm = PackedMat::pack(&pw, rows_n, cols_n, &pq).unwrap();
    let f32_bytes = pw.len() * 4;
    println!(
        "\n-- packed storage: {} B packed vs {} B f32 ({:.3}x, budget 1/6 = 0.167) --",
        pm.bytes(),
        f32_bytes,
        pm.bytes() as f64 / f32_bytes as f64
    );
    rows.push(metric_row("packed_bytes_per_layer", pm.bytes() as f64, "bytes"));
    rows.push(metric_row("f32_bytes_per_layer", f32_bytes as f64, "bytes"));
    rows.push(metric_row(
        "packed_f32_ratio",
        pm.bytes() as f64 / f32_bytes as f64,
        "ratio",
    ));

    let px: Vec<f32> = (0..cols_n * b_cols).map(|_| rng.normal()).collect();
    let wq: Vec<f32> = pw.iter().map(|&v| pq.qdq(v)).collect();
    let wmat = Mat::from_vec(rows_n, cols_n, wq).unwrap();
    let xmat = Mat::from_vec(cols_n, b_cols, px.clone()).unwrap();
    let threads = resolve_threads(0);
    let mut fused_out = Vec::new();
    let fused = bench_with_budget(
        &format!("packed_fused_matmul_{rows_n}x{cols_n}_b{b_cols}"),
        Duration::from_secs(2),
        || {
            pm.fused_matmul_into(&px, b_cols, None, None, threads, &mut fused_out);
            black_box(fused_out.len());
        },
    );
    let dense = bench_with_budget(
        &format!("f32_dense_matmul_{rows_n}x{cols_n}_b{b_cols}"),
        Duration::from_secs(2),
        || {
            black_box(wmat.matmul(&xmat).unwrap());
        },
    );
    let speedup = dense.median_ns / fused.median_ns;
    println!(
        "  fused {:.3} ms vs dense f32 {:.3} ms -> packed_fused_matmul_vs_f32 {:.2}x ({} threads)",
        fused.median_ns / 1e6,
        dense.median_ns / 1e6,
        speedup,
        threads
    );
    rows.push(fused.to_json());
    rows.push(dense.to_json());
    rows.push(metric_row("packed_fused_matmul_vs_f32", speedup, "x"));

    // non-fatal: the measurements above are already printed; don't discard
    // a completed run over an unwritable path
    let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_quant.json".to_string());
    match write_json_rows(Path::new(&path), rows) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
