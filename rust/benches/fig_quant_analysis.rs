//! Bench: regenerate Figures 1, 2, 4 and 8 (the quantizer-analysis
//! figures) — calibration histograms, bit-width capacity curves, the
//! four-strategy AAL comparison, and weight histograms.
use msfp::config::Scale;
use msfp::data::Corpus;
use msfp::exp::{figures, Report};
use msfp::pipeline::Pipeline;

fn main() {
    let dir = Pipeline::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP fig_quant_analysis: artifacts not built");
        return;
    }
    let pl = Pipeline::new(&dir, Scale::from_env()).unwrap();
    let report = Report::new(&pl.runs_dir).unwrap();
    let p = pl.prepare(Corpus::CelebaSyn).unwrap();
    let t0 = std::time::Instant::now();
    figures::fig1(&pl, &report, &p).unwrap();
    figures::fig2(&pl, &report, &p).unwrap();
    let (improved, total) = figures::fig4(&pl, &report, &p, 4).unwrap();
    figures::fig8(&pl, &report, &p).unwrap();
    println!(
        "fig_quant_analysis done in {:.1}s (fig4: unsigned+zp wins {improved}/{total} AALs)",
        t0.elapsed().as_secs_f64()
    );
}
