//! Perf A/B (L1 structural): compare quantized-eval latency across kernel
//! block shapes. Pass an alternative artifacts dir with the re-lowered
//! graph via MSFP_AB_DIR; the baseline comes from ./artifacts.
use std::sync::Arc;
use std::time::Instant;

use msfp::lora::hub::AllocStrategy;
use msfp::lora::Router;
use msfp::model::manifest::Manifest;
use msfp::model::ParamStore;
use msfp::pipeline::Pipeline;
use msfp::runtime::Engine;
use msfp::util::rng::Rng;

fn measure(dir: &std::path::Path, file: &str, label: &str) {
    let base = Pipeline::default_artifacts_dir();
    let m = Manifest::load(&base).unwrap();
    let info = m.model("ddim16").unwrap().clone();
    let engine = Arc::new(Engine::new(dir).unwrap());
    // copy manifest deps from base dir when measuring the AB dir
    let exe = match engine.load(file) {
        Ok(e) => e,
        Err(e) => {
            println!("SKIP {label}: {e:#}");
            return;
        }
    };
    let params = ParamStore::load_init(&info, &base).unwrap().flat;
    let mut rng = Rng::new(1);
    let b = 8usize;
    let mut qp = Vec::new();
    for _ in 0..info.n_layers {
        qp.extend_from_slice(&[1.0, 2.0, 1.0, 1.0, 4.0, 2.0, 1.0, -0.2]);
    }
    let router = Router::init(&info, &mut rng);
    let _ = AllocStrategy::Learned;
    let sel = router.selection_onehot(5.0, &[1.0; 4]);
    let x = vec![0.2f32; info.x_size(b)];
    let t = vec![5.0f32; b];
    let cond = vec![0.0f32; b];
    let hw = info.cfg.img_hw as i64;
    let l = info.n_layers as i64;
    let lora = vec![0.0f32; info.lora_size];
    let run = || {
        exe.run(&[
            (&params[..], &[params.len() as i64]),
            (&qp[..], &[l, 8]),
            (&lora[..], &[lora.len() as i64]),
            (&sel[..], &[l, 4]),
            (&x[..], &[b as i64, hw, hw, info.cfg.in_ch as i64]),
            (&t[..], &[b as i64]),
            (&cond[..], &[b as i64]),
        ])
        .unwrap()
    };
    run(); // warmup
    let n = 12;
    let t0 = Instant::now();
    for _ in 0..n {
        run();
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / n as f64;
    println!("{label}: {ms:.2} ms/eval (b=8)");
}

fn main() {
    let base = Pipeline::default_artifacts_dir();
    if !base.join("manifest.json").exists() {
        println!("SKIP perf_l1_blocks: artifacts not built");
        return;
    }
    measure(&base, "ddim16_q_b8.hlo.txt", "BLOCK_ROWS=8 (baseline)");
    if let Ok(ab) = std::env::var("MSFP_AB_DIR") {
        measure(std::path::Path::new(&ab), "ddim16_q_b8.hlo.txt", "BLOCK_ROWS=64 (candidate)");
    } else {
        println!("set MSFP_AB_DIR=<dir> to measure a re-lowered candidate");
    }
}
