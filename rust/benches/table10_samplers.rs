//! Bench: regenerate paper Table 10 on the synthetic substrate.
//! Runs at the env-selected scale (MSFP_SCALE=fast default; =full for the
//! paper protocol). Reduced budgets are printed, never silent.
use msfp::config::Scale;
use msfp::exp::{tables, Report};
use msfp::pipeline::Pipeline;

fn main() {
    let dir = Pipeline::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP table10_samplers: artifacts not built (make artifacts)");
        return;
    }
    let mut scale = Scale::from_env();
    if std::env::var("MSFP_BENCH_HEAVY").is_err() {
        // reduced budget so the whole bench suite stays tractable; printed,
        // never silent (MSFP_BENCH_HEAVY=1 for the env-selected scale)
        scale.eval_n = 32;
        scale.ref_n = 64;
        scale.steps = 5;
        scale.ft_epochs = 1;
        scale.traj_samples = 4;
        scale.calib_rounds = 2;
        println!("table10_samplers: REDUCED budget (eval_n=32, steps=5, 1 epoch)");
    }
    println!("table10_samplers: scale = {scale:?}");
    let pl = Pipeline::new(&dir, scale).unwrap();
    let report = Report::new(&pl.runs_dir).unwrap();
    let t0 = std::time::Instant::now();
    tables::run_table(&pl, &report, "t10").unwrap();
    println!("table10_samplers done in {:.1}s", t0.elapsed().as_secs_f64());
}
