//! Bench: regenerate Figures 3, 6, 7 and 9 (the fine-tuning figures) —
//! DFA loss alignment, sample grids across bit-widths, and the router's
//! LoRA-allocation distributions at h=2 and h=4.
use msfp::config::Scale;
use msfp::data::Corpus;
use msfp::exp::{figures, Report};
use msfp::pipeline::Pipeline;

fn main() {
    let dir = Pipeline::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP fig_finetune_analysis: artifacts not built");
        return;
    }
    let pl = Pipeline::new(&dir, Scale::from_env()).unwrap();
    let report = Report::new(&pl.runs_dir).unwrap();
    let p = pl.prepare(Corpus::CelebaSyn).unwrap();
    let t0 = std::time::Instant::now();
    figures::fig3(&pl, &report, &p).unwrap();
    figures::fig6(&pl, &report, &p).unwrap();
    figures::fig7_9(&pl, &report, &p, 2).unwrap();
    figures::fig7_9(&pl, &report, &p, 4).unwrap();
    println!("fig_finetune_analysis done in {:.1}s", t0.elapsed().as_secs_f64());
}
