//! Bench: regenerate paper Table 5 on the synthetic substrate.
//! Runs at the env-selected scale (MSFP_SCALE=fast default; =full for the
//! paper protocol). Reduced budgets are printed, never silent.
use msfp::config::Scale;
use msfp::exp::{tables, Report};
use msfp::pipeline::Pipeline;

fn main() {
    let dir = Pipeline::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP table5_searchspace: artifacts not built (make artifacts)");
        return;
    }
    let scale = Scale::from_env();
    println!("table5_searchspace: scale = {scale:?}");
    let pl = Pipeline::new(&dir, scale).unwrap();
    let report = Report::new(&pl.runs_dir).unwrap();
    let t0 = std::time::Instant::now();
    tables::run_table(&pl, &report, "t5").unwrap();
    println!("table5_searchspace done in {:.1}s", t0.elapsed().as_secs_f64());
}
