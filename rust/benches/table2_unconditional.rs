//! Bench: regenerate paper Table 2 (unconditional generation).
//! The full three-corpus sweep is heavy; by default this runs the cifar-syn
//! column and says so — set MSFP_BENCH_HEAVY=1 for all three corpora.
use msfp::config::Scale;
use msfp::data::Corpus;
use msfp::exp::{tables, Report};
use msfp::pipeline::Pipeline;

fn main() {
    let dir = Pipeline::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP table2: artifacts not built (make artifacts)");
        return;
    }
    let heavy = std::env::var("MSFP_BENCH_HEAVY").is_ok();
    let corpora: &[Corpus] = if heavy {
        &[Corpus::CifarSyn, Corpus::BedroomSyn, Corpus::ChurchSyn]
    } else {
        println!("table2: running cifar-syn only (MSFP_BENCH_HEAVY=1 for all corpora)");
        &[Corpus::CifarSyn]
    };
    let mut scale = Scale::from_env();
    if !heavy {
        scale.eval_n = 32;
        scale.ref_n = 64;
        scale.steps = 5;
        scale.ft_epochs = 1;
        scale.traj_samples = 4;
        scale.calib_rounds = 2;
        println!("table2: REDUCED budget (eval_n=32, steps=5, 1 epoch)");
    }
    let pl = Pipeline::new(&dir, scale).unwrap();
    let report = Report::new(&pl.runs_dir).unwrap();
    let t0 = std::time::Instant::now();
    tables::table2(&pl, &report, corpora).unwrap();
    println!("table2 done in {:.1}s", t0.elapsed().as_secs_f64());
}
