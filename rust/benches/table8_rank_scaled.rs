//! Bench: regenerate paper Table 8 on the synthetic substrate.
//! Runs at the env-selected scale (MSFP_SCALE=fast default; =full for the
//! paper protocol). Reduced budgets are printed, never silent.
use msfp::config::Scale;
use msfp::exp::{tables, Report};
use msfp::pipeline::Pipeline;

fn main() {
    let dir = Pipeline::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP table8_rank_scaled: artifacts not built (make artifacts)");
        return;
    }
    let scale = Scale::from_env();
    println!("table8_rank_scaled: scale = {scale:?}");
    let pl = Pipeline::new(&dir, scale).unwrap();
    let report = Report::new(&pl.runs_dir).unwrap();
    let t0 = std::time::Instant::now();
    tables::run_table(&pl, &report, "t8").unwrap();
    println!("table8_rank_scaled done in {:.1}s", t0.elapsed().as_secs_f64());
}
