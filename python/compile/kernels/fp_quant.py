"""L1 Pallas kernel: mixup-sign floating-point fake quantize-dequantize.

This is the deployed quantizer of the MSFP framework: every quantized layer
in the serving graphs (``*_q_b*.hlo.txt``) funnels its weights and input
activations through this kernel. It is an elementwise VPU pipeline; on TPU
it would tile HBM->VMEM in (BLOCK_ROWS, 128) blocks with double-buffered row
streaming (see DESIGN.md §6). On this image it must run ``interpret=True``:
real TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot
execute.

The numerics are the contract defined in ref.py (exponent bit-extraction,
bit-assembled powers of two, half-up rounding) so the kernel, the jnp
reference and the Rust mirror agree bit-for-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane width of the TPU VPU; blocks are (BLOCK_ROWS, LANES).
LANES = 128
BLOCK_ROWS = 64


def _exp2_int(k):
    k = k.astype(jnp.int32)
    return jax.lax.bitcast_convert_type((k + 127) << 23, jnp.float32)


def _floor_log2(x):
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    exp_field = (bits >> 23) & 0xFF
    mant = bits & 0x7FFFFF
    normal_e = exp_field - 127
    sub_e = (31 - jax.lax.clz(mant)) - 149
    e = jnp.where(exp_field == 0, sub_e, normal_e)
    return jnp.where((mant == 0) & (exp_field == 0), jnp.int32(-200), e)


def _rnd(v):
    return jnp.floor(v + 0.5)


def _mixup_qdq_block(x, sign, maxval, e_bits, m_bits, zp):
    """Elementwise mixup-sign qdq on one block; mirrors ref.mixup_qdq,
    including the e_bits < 0 INT-baseline dispatch."""
    e_sel = e_bits
    e_bits = jnp.maximum(e_bits, 0.0).astype(jnp.int32)
    m_i = m_bits.astype(jnp.int32)
    full = 2.0 - _exp2_int(-m_i)
    a = maxval / full
    e_min = jnp.maximum(-((jnp.int32(1) << e_bits) - 1), -100)

    # signed FP branch
    ys = jnp.clip(x / a, -full, full)
    es = jnp.clip(_floor_log2(jnp.abs(ys)), e_min, 0)
    ss = _exp2_int(es - m_i)
    qs = _rnd(ys / ss) * ss * a

    # unsigned + zero-point FP branch
    yu = jnp.clip((x - zp) / a, 0.0, full)
    eu = jnp.clip(_floor_log2(yu), e_min, 0)
    su = _exp2_int(eu - m_i)
    qu = _rnd(yu / su) * su * a + zp

    fp = jnp.where(sign >= 0.5, qs, qu)

    # INT branches (n = m_bits): symmetric / asymmetric on [zp, maxval]
    qmax = ((jnp.int32(1) << (m_i - 1)) - 1).astype(jnp.float32)
    si = maxval / qmax
    ii_s = jnp.clip(_rnd(x / si), -qmax - 1.0, qmax) * si
    levels = ((jnp.int32(1) << m_i) - 1).astype(jnp.float32)
    sa = (maxval - zp) / levels
    sa = jnp.where(sa <= 0.0, 1.0, sa)
    za = _rnd(-zp / sa)
    ii_a = (jnp.clip(_rnd(x / sa) + za, 0.0, levels) - za) * sa
    ii = jnp.where(sign >= 0.5, ii_s, ii_a)

    return jnp.where(e_sel >= 0.0, fp, ii)


def _kernel(p_ref, x_ref, o_ref):
    # p_ref: (8,) f32 — [sign, maxval, e_bits, m_bits, zp, _, _, _]
    sign = p_ref[0]
    maxval = p_ref[1]
    e_bits = p_ref[2]
    m_bits = p_ref[3]
    zp = p_ref[4]
    o_ref[...] = _mixup_qdq_block(x_ref[...], sign, maxval, e_bits, m_bits, zp)


def mixup_qdq_pallas(x, sign, maxval, e_bits, m_bits, zp):
    """Mixup-sign fake-qdq of an arbitrary-shape f32 array via Pallas.

    Scalar quantizer parameters are packed into an (8,) params vector and
    broadcast to every block; the data is flattened, padded to a
    (rows, LANES) layout and streamed block-by-block.
    """
    params = jnp.stack(
        [
            jnp.asarray(sign, jnp.float32),
            jnp.asarray(maxval, jnp.float32),
            jnp.asarray(e_bits, jnp.float32),
            jnp.asarray(m_bits, jnp.float32),
            jnp.asarray(zp, jnp.float32),
            jnp.float32(0),
            jnp.float32(0),
            jnp.float32(0),
        ]
    )
    shape = x.shape
    n = x.size
    block = BLOCK_ROWS * LANES
    rows = max(1, -(-n // LANES))
    # pad rows to a multiple of BLOCK_ROWS
    rows = -(-rows // BLOCK_ROWS) * BLOCK_ROWS
    padded = rows * LANES
    xf = jnp.pad(x.reshape(-1), (0, padded - n)).reshape(rows, LANES)

    out = pl.pallas_call(
        _kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((8,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(params, xf)
    return out.reshape(-1)[:n].reshape(shape)


def signed_qdq_pallas(x, maxval, e_bits, m_bits):
    """Signed-only convenience wrapper (weight quantization path)."""
    return mixup_qdq_pallas(x, 1.0, maxval, e_bits, m_bits, 0.0)


def unsigned_qdq_pallas(x, maxval, e_bits, m_bits, zp):
    """Unsigned + zero-point convenience wrapper (AAL activation path)."""
    return mixup_qdq_pallas(x, 0.0, maxval, e_bits, m_bits, zp)
