"""L1 Pallas kernel: fused quantized-linear + LoRA correction.

Computes  y = qdq_signed(W) @ x + scale * B @ (A @ x)
with W: [N, K], x: [K, B], A: [r, K], B: [N, r].

This is the MXU-facing hot spot of the serving path: the attention qkv/proj
and time-embedding linears of the quantized UNet route through it. The grid
tiles the output rows (one block of W rows per program); the dequantized
weight block is staged in VMEM and the rank-r LoRA correction is fused into
the same block accumulation (r << BLOCK_N keeps A, B resident). On TPU the
natural tiling is (128, 128) MXU blocks; here the kernel runs under
``interpret=True`` (see fp_quant.py) and the block shape is sized for test
speed.

Numerics contract: identical to ref.lora_qmatmul_ref (which composes
ref.fp_qdq_signed with two jnp matmuls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fp_quant

BLOCK_N = 64


def _kernel(p_ref, w_ref, x_ref, a_ref, b_ref, o_ref):
    # p_ref: (8,) f32 — [scale, maxval, e_bits, m_bits, _, _, _, _]
    scale = p_ref[0]
    maxval = p_ref[1]
    e_bits = p_ref[2]
    m_bits = p_ref[3]
    wq = fp_quant._mixup_qdq_block(
        w_ref[...], jnp.float32(1.0), maxval, e_bits, m_bits, jnp.float32(0.0)
    )
    ax = a_ref[...] @ x_ref[...]          # [r, B] — recomputed per block; r is tiny
    o_ref[...] = wq @ x_ref[...] + scale * (b_ref[...] @ ax)


def lora_qmatmul_pallas(w, x, a, b, scale, maxval, e_bits, m_bits):
    """Fused qdq-matmul + LoRA. w: [N,K], x: [K,B], a: [r,K], b: [N,r]."""
    n, k = w.shape
    _, bs = x.shape
    r = a.shape[0]
    params = jnp.stack(
        [
            jnp.asarray(scale, jnp.float32),
            jnp.asarray(maxval, jnp.float32),
            jnp.asarray(e_bits, jnp.float32),
            jnp.asarray(m_bits, jnp.float32),
            jnp.float32(0),
            jnp.float32(0),
            jnp.float32(0),
            jnp.float32(0),
        ]
    )
    n_pad = -(-n // BLOCK_N) * BLOCK_N
    w_p = jnp.pad(w, ((0, n_pad - n), (0, 0)))
    b_p = jnp.pad(b, ((0, n_pad - n), (0, 0)))

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((8,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_N, k), lambda i: (i, 0)),
            pl.BlockSpec((k, bs), lambda i: (0, 0)),
            pl.BlockSpec((r, k), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_N, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, bs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, bs), jnp.float32),
        interpret=True,
    )(params, w_p, x, a, b_p)
    return out[:n]
