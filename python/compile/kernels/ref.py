"""Pure-jnp oracle for the MSFP quantization kernels.

This module defines the *numerics contract* shared by three implementations:
  1. this reference (used by training graphs, where autodiff needs STE),
  2. the Pallas kernels in fp_quant.py / lora_qmatmul.py (the deployed path),
  3. the Rust mirror in rust/src/quant/ (used by the MSFP parameter search).

All three must agree bit-for-bit on f32 inputs. To make that possible the
implementation avoids transcendental functions whose last-ulp behaviour
differs across libms:

  * floor(log2|x|) is computed by IEEE-754 exponent extraction
    (bitcast + shift), exact for normals and subnormals alike;
  * powers of two 2^k are constructed by bit assembly ((k+127)<<23),
    exact for k in [-126, 127];
  * rounding is rnd(v) = floor(v + 0.5) (deterministic half-up), identical
    on XLA and rustc.

Quantizer definition (paper Eq. 6 / Eq. 8 / Eq. 10):
An ExMy floating-point grid anchored at `maxval` with full mantissa range
[1, 2 - 2^-m]. We normalize y = x / a with a = maxval / (2 - 2^-m) so the
top binade of the normalized grid is [1, 2). Normal binades span
e in [E_min, 0], E_min = -(2^e_bits - 1); below 2^E_min the grid degrades
to the uniform subnormal grid with step 2^(E_min - m), which includes 0.
e_bits = 0 therefore yields a uniform (INT-like) grid — the E0My formats of
the paper's search space.

The paper's Eq. 10 prints maxval = 2^(2^x-1-b) * (1 - 2^-y); that drops the
implicit leading 1 of the mantissa in Eq. 6. We follow Eq. 6: the largest
mantissa is 1 + (2^y - 1)/2^y = 2 - 2^-y. See DESIGN.md §3.1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _exp2_int(k):
    """Exact 2^k for integer-valued k (int32 array), k in [-126, 127]."""
    k = jnp.asarray(k).astype(jnp.int32)
    bits = (k + 127) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _floor_log2(x):
    """floor(log2(x)) for x > 0 via IEEE-754 exponent extraction (exact).

    Subnormal inputs are handled by counting the leading zeros of the
    mantissa field. x == 0 maps to a large negative sentinel (-200) which
    every caller clamps away.
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    exp_field = (bits >> 23) & 0xFF
    mant = bits & 0x7FFFFF
    # Normal numbers: unbiased exponent.
    normal_e = exp_field - 127
    # Subnormals: value = mant * 2^-149, floor(log2) = (bitlen(mant)-1) - 149.
    sub_e = (31 - jax.lax.clz(mant)) - 149
    e = jnp.where(exp_field == 0, sub_e, normal_e)
    return jnp.where((mant == 0) & (exp_field == 0), jnp.int32(-200), e)


def _rnd(v):
    """Deterministic half-up rounding: floor(v + 0.5)."""
    return jnp.floor(v + 0.5)


def fp_qdq_signed(x, maxval, e_bits, m_bits):
    """Signed ExMy fake quantize-dequantize (paper Eq. 6), s = 1.

    x: f32 array. maxval: positive scalar (grid anchor). e_bits/m_bits:
    integer-valued scalars (may arrive as f32; converted).
    """
    e_bits = jnp.asarray(e_bits).astype(jnp.int32)
    m_bits = jnp.asarray(m_bits).astype(jnp.int32)
    maxval = jnp.asarray(maxval, jnp.float32)
    full = 2.0 - _exp2_int(-m_bits)  # 2 - 2^-m, exact
    a = maxval / full
    y = jnp.clip(x / a, -full, full)
    ay = jnp.abs(y)
    # e_min floored at -100 so step = 2^(e_min - m) stays a normal f32 for
    # any mantissa width (shared contract with quant::fp::e_min_of).
    e_min = jnp.maximum(-((jnp.int32(1) << e_bits) - 1), -100)
    e = jnp.clip(_floor_log2(ay), e_min, 0)
    step = _exp2_int(e - m_bits)
    q = _rnd(y / step) * step
    return q * a


def fp_qdq_unsigned(x, maxval, e_bits, m_bits, zp):
    """Unsigned ExMy fake quantize-dequantize with zero point (paper Eq. 8).

    The grid covers [zp, maxval + zp] (zp <= 0 recovers the SiLU trough
    [-0.278, 0)). s = 0, so e + m = n for an n-bit format.
    """
    e_bits = jnp.asarray(e_bits).astype(jnp.int32)
    m_bits = jnp.asarray(m_bits).astype(jnp.int32)
    maxval = jnp.asarray(maxval, jnp.float32)
    zp = jnp.asarray(zp, jnp.float32)
    full = 2.0 - _exp2_int(-m_bits)
    a = maxval / full
    y = jnp.clip((x - zp) / a, 0.0, full)
    e_min = jnp.maximum(-((jnp.int32(1) << e_bits) - 1), -100)
    e = jnp.clip(_floor_log2(y), e_min, 0)
    step = _exp2_int(e - m_bits)
    q = _rnd(y / step) * step
    return q * a + zp


def mixup_qdq(x, sign, maxval, e_bits, m_bits, zp):
    """Mixup-sign dispatch. The per-layer activation quantizer of MSFP.

    Row semantics (also implemented by the Pallas kernel and the Rust
    mirror):
      e_bits >= 0, sign >= 0.5  -> signed ExMy FP grid
      e_bits >= 0, sign <  0.5  -> unsigned ExMy FP grid + zero point zp
      e_bits <  0, sign >= 0.5  -> symmetric INT, n = m_bits (baselines)
      e_bits <  0, sign <  0.5  -> asymmetric INT on [zp, maxval], n = m_bits

    The INT rows let the INT-PTQ baselines (Q-Diffusion/EfficientDM-like)
    reuse the same serving/fine-tune artifacts; the Rust-side search decides
    which row each layer gets. sign/format/zp are runtime scalars in
    qparams[L, 8].
    """
    sign = jnp.asarray(sign, jnp.float32)
    e_sel = jnp.asarray(e_bits, jnp.float32)
    e_fp = jnp.maximum(e_sel, 0.0)
    s = fp_qdq_signed(x, maxval, e_fp, m_bits)
    u = fp_qdq_unsigned(x, maxval, e_fp, m_bits, zp)
    fp = jnp.where(sign >= 0.5, s, u)
    i_s = int_qdq_sym(x, maxval, m_bits)
    i_a = int_qdq_asym(x, zp, maxval, m_bits)
    i = jnp.where(sign >= 0.5, i_s, i_a)
    return jnp.where(e_sel >= 0.0, fp, i)


def weight_qdq(x, maxval, e_bits, m_bits):
    """Weight quantizer dispatch: signed FP grid, or symmetric INT if
    e_bits < 0 (INT baselines)."""
    e_sel = jnp.asarray(e_bits, jnp.float32)
    fp = fp_qdq_signed(x, maxval, jnp.maximum(e_sel, 0.0), m_bits)
    i = int_qdq_sym(x, maxval, m_bits)
    return jnp.where(e_sel >= 0.0, fp, i)


def int_qdq_sym(x, maxval, n_bits):
    """Symmetric uniform INT fake quant (baseline: Q-Diffusion-like weights)."""
    n_bits = jnp.asarray(n_bits).astype(jnp.int32)
    qmax = ((jnp.int32(1) << (n_bits - 1)) - 1).astype(jnp.float32)
    s = jnp.asarray(maxval, jnp.float32) / qmax
    q = jnp.clip(_rnd(x / s), -qmax - 1.0, qmax)
    return q * s


def int_qdq_asym(x, lo, hi, n_bits):
    """Asymmetric uniform INT fake quant (baseline for activations)."""
    n_bits = jnp.asarray(n_bits).astype(jnp.int32)
    levels = ((jnp.int32(1) << n_bits) - 1).astype(jnp.float32)
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    s = (hi - lo) / levels
    s = jnp.where(s <= 0.0, 1.0, s)
    z = _rnd(-lo / s)
    q = jnp.clip(_rnd(x / s) + z, 0.0, levels)
    return (q - z) * s


def ste(fn, x, *args):
    """Straight-through estimator: forward fn(x), identity backward in x."""
    return x + jax.lax.stop_gradient(fn(x, *args) - x)


def fp_qdq_signed_ste(x, maxval, e_bits, m_bits):
    return ste(fp_qdq_signed, x, maxval, e_bits, m_bits)


def weight_qdq_ste(x, maxval, e_bits, m_bits):
    return ste(weight_qdq, x, maxval, e_bits, m_bits)


def mixup_qdq_ste(x, sign, maxval, e_bits, m_bits, zp):
    return ste(mixup_qdq, x, sign, maxval, e_bits, m_bits, zp)


def int_qdq_sym_ste(x, maxval, n_bits):
    return ste(int_qdq_sym, x, maxval, n_bits)


def int_qdq_asym_ste(x, lo, hi, n_bits):
    return ste(int_qdq_asym, x, lo, hi, n_bits)


def lora_qmatmul_ref(w, x, a, b, scale, maxval, e_bits, m_bits):
    """Oracle for the fused quantized-linear + LoRA kernel.

    y = qdq_signed(W) @ x + scale * B @ (A @ x)
    W: [N, K], x: [K, B], A: [r, K], B: [N, r].
    """
    wq = fp_qdq_signed(w, maxval, e_bits, m_bits)
    return wq @ x + scale * (b @ (a @ x))
