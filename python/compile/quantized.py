"""L2: TALoRA router + training-step graphs (pretrain / fine-tune).

The fine-tune graph is where the paper's three techniques compose:
  * MSFP quantizers (qparams rows, searched in Rust) applied with STE,
  * TALoRA: per-layer LoRA hub + the timestep-aware router, trained jointly
    (hard selection forward, straight-through softmax backward),
  * DFA: the denoising-factor gamma_t (computed by the Rust schedule,
    paper Eq. 4) scales the eps-MSE loss (paper Eq. 9).

Rust executes these graphs via PJRT and owns the Adam state; each graph is a
pure function returning (loss, grads...).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M


def router_select(cfg, n_layers, router_flat, t, hub_mask):
    """Timestep-aware router: t -> one-hot LoRA selection per layer.

    router_flat packs W [temb_dim, L*H] then b [L*H]. Forward uses the hard
    argmax one-hot; backward flows through the per-layer softmax (STE, [1]
    in the paper). hub_mask[H] in {0,1} disables hub slots (h=2 runs mask
    slots 2,3 of the H=4 hub). Mirrored for inference by
    rust/src/lora/router.rs (golden-tested).
    """
    H = cfg.lora_hub
    d = cfg.temb_dim
    w = router_flat[:d * n_layers * H].reshape(d, n_layers * H)
    b = router_flat[d * n_layers * H:]
    temb = M.sinusoidal_temb(jnp.asarray(t, jnp.float32), d)
    logits = (temb @ w + b).reshape(n_layers, H)
    logits = logits + (hub_mask - 1.0) * 1e9
    soft = jax.nn.softmax(logits, axis=-1)
    hard = jax.nn.one_hot(jnp.argmax(logits, axis=-1), H)
    return soft + jax.lax.stop_gradient(hard - soft)


def pretrain_loss(cfg, meta, flat, x0, noise, t, abar, cond):
    """DDPM eps-prediction loss (paper Eq. 1): x_t built in-graph."""
    a = jnp.sqrt(abar)[:, None, None, None]
    s = jnp.sqrt(1.0 - abar)[:, None, None, None]
    x_t = a * x0 + s * noise
    eps = M.apply_fp(cfg, meta, flat, x_t, t, cond)
    return jnp.mean((eps - noise) ** 2)


def make_pretrain_step(cfg, meta):
    def step(flat, x0, noise, t, abar, cond):
        loss, g = jax.value_and_grad(
            lambda f: pretrain_loss(cfg, meta, f, x0, noise, t, abar, cond)
        )(flat)
        return loss, g
    return step


def finetune_loss(cfg, meta, flat, qparams, lora, router, hub_mask,
                  x_t, t, gamma, eps_target, cond):
    """DFA-aligned fine-tune loss (paper Eq. 7 + Eq. 9).

    The whole batch shares one timestep t (trajectory fine-tuning walks the
    denoising process step by step), so the router picks one LoRA per layer
    per step — exactly the TALoRA inference regime.
    """
    n_layers = meta["n_layers"]
    sel = router_select(cfg, n_layers, router, t, hub_mask)
    tb = jnp.full((x_t.shape[0],), t, jnp.float32)
    eps_q = M.apply_quant(cfg, meta, flat, qparams, lora, sel, x_t, tb, cond,
                          mode="qtrain")
    return gamma * jnp.mean((eps_q - eps_target) ** 2), sel


def make_finetune_step(cfg, meta):
    def step(flat, qparams, lora, router, hub_mask, x_t, t, gamma,
             eps_target, cond):
        def lossfn(lo, ro):
            loss, sel = finetune_loss(cfg, meta, flat, qparams, lo, ro,
                                      hub_mask, x_t, t, gamma, eps_target,
                                      cond)
            return loss, sel
        (loss, sel), (g_lora, g_router) = jax.value_and_grad(
            lossfn, argnums=(0, 1), has_aux=True)(lora, router)
        return loss, g_lora, g_router, sel
    return step
