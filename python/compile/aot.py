"""AOT compiler: lower every graph the Rust coordinator needs to HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published `xla` 0.1.6 crate links) rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
  manifest.json                 the ABI: models, param/layer specs, artifact
                                table, input signatures
  <model>_init.f32              seeded initial parameters (raw LE f32)
  <model>_{fp,q}_b{B}.hlo.txt   forward graphs (fp / quantized+TALoRA serve)
  <model>_calib_b8.hlo.txt      fp forward + per-layer activation capture
  <model>_pretrain_b8.hlo.txt   DDPM loss + grad(params)
  <model>_finetune_b8.hlo.txt   DFA loss + grad(lora, router) + router sel
  features{16,32}.hlo.txt       fixed random-conv feature extractor (eval)
  golden/quant_golden.json      ref-kernel test vectors for the Rust mirror
  golden/router_golden.json     router selections for the Rust mirror

Per-artifact caching: a stamp records the sha256 of python/compile sources;
artifacts are re-lowered only when sources change or --force is given.
Python runs only here — never on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import quantized as Q
from .kernels import ref

BATCHES_FP = (1, 4, 8)
BATCHES_Q = (1, 2, 4, 8)
TRAIN_B = 8
CALIB_B = 8
EVAL_B = 32
ACT_SAMPLES = 512


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default print elides big constants as
    # `{...}`, which the HLO text parser on the Rust side silently reads
    # back as zeros (bit us via the baked feature-extractor weights).
    return comp.as_hlo_text(print_large_constants=True)


def _src_hash() -> str:
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# --------------------------------------------------------------------------
# feature extractor (fixed random weights — the FID-syn embedding)
# --------------------------------------------------------------------------

def _feature_weights(hw):
    rng = np.random.default_rng(7)
    chans = [3, 32, 64, 64] if hw == 16 else [3, 32, 64, 64, 64]
    ws = []
    for cin, cout in zip(chans[:-1], chans[1:]):
        ws.append((rng.normal(size=(3, 3, cin, cout))
                   * math.sqrt(2.0 / (9 * cin))).astype(np.float32))
    wl = (rng.normal(size=(64, 10)) * 0.3).astype(np.float32)
    return ws, wl


def make_features(hw):
    ws, wl = _feature_weights(hw)

    def feats(img):
        h = img
        for w in ws:
            h = jax.lax.conv_general_dilated(
                h, jnp.asarray(w), (2, 2), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            h = jnp.tanh(h)
        sfeat = h.reshape(h.shape[0], -1)          # [B, 2*2*64]
        feat = jnp.mean(h, axis=(1, 2))            # [B, 64]
        logits = feat @ jnp.asarray(wl)            # [B, 10]
        return feat, sfeat, logits

    return feats


# --------------------------------------------------------------------------
# goldens for the Rust mirror
# --------------------------------------------------------------------------

def quant_golden():
    rng = np.random.default_rng(11)
    arrays = {
        "normal": (rng.normal(size=96) * 2.0).astype(np.float32),
        "silu": (np.maximum(rng.normal(size=96) * 3.0, 0)
                 - 0.25 * rng.random(96)).astype(np.float32),
        "uniform": (rng.random(96) * 5.0 - 1.0).astype(np.float32),
        "tiny": (rng.normal(size=96) * 1e-3).astype(np.float32),
    }
    rows = [
        # [sign, maxval, e_bits, m_bits, zp]
        [1.0, 2.7, 2, 1, 0.0], [1.0, 1.3, 1, 2, 0.0], [1.0, 4.0, 3, 2, 0.0],
        [1.0, 0.9, 0, 3, 0.0], [0.0, 2.7, 2, 2, -0.25], [0.0, 3.1, 3, 1, -0.1],
        [0.0, 1.0, 0, 4, -0.3], [0.0, 5.0, 1, 3, 0.0],
        [1.0, 2.0, -1, 4, 0.0], [0.0, 2.0, -1, 4, -0.25],  # INT rows
        [1.0, 6.0, -1, 6, 0.0], [0.0, 6.0, -1, 6, -0.3],
        [1.0, 3.3, -1, 8, 0.0], [0.0, 3.3, -1, 8, -0.2],
    ]
    cases = []
    for aname, arr in arrays.items():
        for row in rows:
            sign, maxval, e, m, zp = row
            out = ref.mixup_qdq(jnp.asarray(arr), sign, maxval, e, m, zp)
            wout = ref.weight_qdq(jnp.asarray(arr), maxval, e, m)
            cases.append({
                "array": aname, "sign": sign, "maxval": maxval,
                "e_bits": e, "m_bits": m, "zp": zp,
                "mixup": [float(v) for v in np.asarray(out)],
                "weight": [float(v) for v in np.asarray(wout)],
            })
    return {"arrays": {k: [float(v) for v in v_] for k, v_ in arrays.items()},
            "cases": cases}


def router_golden(cfg, meta):
    rng = np.random.default_rng(23)
    rsize = meta["router_size"]
    router = (rng.normal(size=rsize) * 0.5).astype(np.float32)
    out = {"temb_dim": cfg.temb_dim, "n_layers": meta["n_layers"],
           "hub": cfg.lora_hub, "router": [float(v) for v in router],
           "cases": []}
    for mask in ([1, 1, 1, 1], [1, 1, 0, 0]):
        for t in range(0, 100, 7):
            sel = Q.router_select(cfg, meta["n_layers"],
                                  jnp.asarray(router), float(t),
                                  jnp.asarray(mask, jnp.float32))
            idx = [int(i) for i in np.argmax(np.asarray(sel), axis=-1)]
            out["cases"].append({"t": t, "mask": mask, "sel": idx})
    return out


# --------------------------------------------------------------------------
# artifact registry
# --------------------------------------------------------------------------

def model_artifacts(name, cfg, meta):
    """Yield (filename, build_fn) for one model variant."""
    L = meta["n_layers"]
    P = meta["n_params"]
    LF = meta["lora_size"]
    RF = meta["router_size"]
    H = cfg.lora_hub
    hw, c = cfg.img_hw, cfg.in_ch

    def xs(b):
        return spec((b, hw, hw, c))

    for b in BATCHES_FP:
        def build(b=b):
            return jax.jit(
                lambda flat, x, t, cond: M.apply_fp(cfg, meta, flat, x, t, cond),
                keep_unused=True,
            ).lower(spec((P,)), xs(b), spec((b,)), spec((b,)))
        yield f"{name}_fp_b{b}.hlo.txt", build

    for b in BATCHES_Q:
        def build(b=b):
            return jax.jit(
                lambda flat, qp, lora, sel, x, t, cond: M.apply_quant(
                    cfg, meta, flat, qp, lora, sel, x, t, cond, mode="serve"),
                keep_unused=True,
            ).lower(spec((P,)), spec((L, 8)), spec((LF,)), spec((L, H)),
                    xs(b), spec((b,)), spec((b,)))
        yield f"{name}_q_b{b}.hlo.txt", build

    def build_calib():
        return jax.jit(
            lambda flat, x, t, cond: M.apply_calib(
                cfg, meta, flat, x, t, cond, samples=ACT_SAMPLES),
            keep_unused=True,
        ).lower(spec((P,)), xs(CALIB_B), spec((CALIB_B,)), spec((CALIB_B,)))
    yield f"{name}_calib_b{CALIB_B}.hlo.txt", build_calib

    def build_pretrain():
        step = Q.make_pretrain_step(cfg, meta)
        return jax.jit(step, keep_unused=True).lower(
            spec((P,)), xs(TRAIN_B), xs(TRAIN_B), spec((TRAIN_B,)),
            spec((TRAIN_B,)), spec((TRAIN_B,)))
    yield f"{name}_pretrain_b{TRAIN_B}.hlo.txt", build_pretrain

    def build_finetune():
        step = Q.make_finetune_step(cfg, meta)
        return jax.jit(step, keep_unused=True).lower(
            spec((P,)), spec((L, 8)), spec((LF,)), spec((RF,)), spec((H,)),
            xs(TRAIN_B), spec(()), spec(()), xs(TRAIN_B), spec((TRAIN_B,)))
    yield f"{name}_finetune_b{TRAIN_B}.hlo.txt", build_finetune


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "..", "artifacts"))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    args = ap.parse_args()
    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "golden"), exist_ok=True)

    stamp_path = os.path.join(out, "stamp.json")
    src = _src_hash()
    stamp = {}
    if os.path.exists(stamp_path) and not args.force:
        with open(stamp_path) as f:
            stamp = json.load(f)
    fresh = stamp.get("src") == src

    def want(fname):
        if args.only and args.only not in fname:
            return False
        path = os.path.join(out, fname)
        return args.force or not (fresh and os.path.exists(path))

    manifest = {"models": {}, "schema": 1}
    t_all = time.time()
    for name, cfg in M.MODELS.items():
        flat, meta = M.init_model(cfg, seed=hash(name) % (2**31))
        init_name = f"{name}_init.f32"
        flat.astype("<f4").tofile(os.path.join(out, init_name))

        arts = {}
        for fname, build in model_artifacts(name, cfg, meta):
            arts[fname.split(".")[0][len(name) + 1:]] = fname
            if not want(fname):
                continue
            t0 = time.time()
            text = to_hlo_text(build())
            with open(os.path.join(out, fname), "w") as f:
                f.write(text)
            print(f"  {fname}: {len(text)/1e6:.1f} MB in {time.time()-t0:.0f}s",
                  flush=True)

        manifest["models"][name] = {
            "cfg": {k: (list(v) if isinstance(v, tuple) else v)
                    for k, v in dataclasses_asdict(cfg).items()},
            "n_params": meta["n_params"], "n_layers": meta["n_layers"],
            "lora_size": meta["lora_size"], "router_size": meta["router_size"],
            "act_samples": ACT_SAMPLES,
            "param_specs": meta["param_specs"],
            "layer_specs": meta["layer_specs"],
            "init_params": init_name,
            "artifacts": arts,
            "batches_fp": list(BATCHES_FP), "batches_q": list(BATCHES_Q),
            "train_b": TRAIN_B, "calib_b": CALIB_B,
        }
        if name == "ddim16":
            with open(os.path.join(out, "golden", "router_golden.json"), "w") as f:
                json.dump(router_golden(cfg, meta), f)

    for hw in (16, 32):
        fname = f"features{hw}.hlo.txt"
        if want(fname):
            feats = make_features(hw)
            lowered = jax.jit(feats).lower(spec((EVAL_B, hw, hw, 3)))
            with open(os.path.join(out, fname), "w") as f:
                f.write(to_hlo_text(lowered))
            print(f"  {fname} done", flush=True)
    manifest["features"] = {"16": "features16.hlo.txt",
                            "32": "features32.hlo.txt",
                            "feat_dim": 64, "sfeat_dim": 256,
                            "n_logits": 10, "batch": EVAL_B}
    manifest["io"] = {
        "fp": ["params[P]", "x[B,H,W,C]", "t[B]", "cond[B]", "-> eps"],
        "q": ["params[P]", "qparams[L,8]", "lora[LF]", "sel[L,H]",
              "x[B,H,W,C]", "t[B]", "cond[B]", "-> eps"],
        "calib": ["params[P]", "x[B,H,W,C]", "t[B]", "cond[B]",
                  "-> (eps, acts[L,S], minmax[L,2])"],
        "pretrain": ["params[P]", "x0", "noise", "t[B]", "abar[B]", "cond[B]",
                     "-> (loss, grad[P])"],
        "finetune": ["params[P]", "qparams[L,8]", "lora[LF]", "router[RF]",
                     "hub_mask[H]", "x_t", "t[]", "gamma[]", "eps_target",
                     "cond[B]", "-> (loss, glora[LF], grouter[RF], sel[L,H])"],
        "features": ["img[B,H,W,3]", "-> (feat[B,64], sfeat[B,256],"
                     " logits[B,10])"],
        "qparams_row": ["w_maxval", "w_ebits(<0 => INT)", "w_mbits",
                        "a_sign", "a_maxval", "a_ebits(<0 => INT)",
                        "a_mbits", "a_zp"],
    }

    with open(os.path.join(out, "golden", "quant_golden.json"), "w") as f:
        json.dump(quant_golden(), f)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(stamp_path, "w") as f:
        json.dump({"src": src}, f)
    print(f"artifacts complete in {time.time()-t_all:.0f}s -> {out}")


def dataclasses_asdict(cfg):
    import dataclasses as dc
    return dc.asdict(cfg)


if __name__ == "__main__":
    main()
