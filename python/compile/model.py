"""L2: the diffusion UNet in JAX, with a mode-switched quantization context.

One architecture definition serves every graph the Rust coordinator loads:

  mode='fp'      full-precision forward (pretraining, FP trajectories)
  mode='qtrain'  fake-quant with STE through ref.py (differentiable; used by
                 the fine-tune graph, where grads flow to LoRA + router)
  mode='serve'   fake-quant through the *Pallas kernels* (the deployed path;
                 lowered into the *_q_b*.hlo.txt serving artifacts)
  mode='calib'   full-precision forward that additionally emits per-layer
                 activation samples + min/max for the Rust MSFP search

Parameters cross the ABI as a single flat f32 vector; ``param_specs`` (name,
shape, offset) is emitted into artifacts/manifest.json so Rust owns the
parameter store. Quantized layers are discovered in call order and recorded
in ``layer_specs``; their per-layer quantizer parameters arrive as a
``qparams[L, 8]`` runtime input laid out as
[w_maxval, w_ebits, w_mbits, a_sign, a_maxval, a_ebits, a_mbits, a_zp].

Model variants (DESIGN.md §2): ``ddim16`` (pixel space 16x16x3, stands in
for the paper's DDIM CIFAR-10/CelebA models), ``ldm8``/``ldm8c`` (latent
space 8x8x4 over a fixed orthogonal patch autoencoder, stands in for
LDM-4/LDM-8 on LSUN/ImageNet; ``ldm8c`` is class-conditional).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import fp_quant
from .kernels import lora_qmatmul


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    img_hw: int
    in_ch: int
    base_ch: int = 32
    ch_mult: tuple = (1, 2)
    temb_dim: int = 64
    groups: int = 8
    n_classes: int = 0  # 0 = unconditional
    lora_rank: int = 4
    lora_hub: int = 4  # H; h=2 runs mask slots 2/3 (see quantized.py)


MODELS = {
    "ddim16": ModelCfg("ddim16", 16, 3),
    "ldm8": ModelCfg("ldm8", 8, 4),
    "ldm8c": ModelCfg("ldm8c", 8, 4, n_classes=10),
}


def sinusoidal_temb(t, dim):
    """Sinusoidal timestep embedding; mirrored in rust/src/model/temb.rs."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    args = jnp.asarray(t, jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)


def group_norm(x, scale, bias, groups, eps=1e-5):
    """GroupNorm over NHWC (kept full precision, as in the paper)."""
    b, h, w, c = x.shape
    g = groups
    xg = x.reshape(b, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(b, h, w, c) * scale + bias


class Ctx:
    """Walks the UNet, owning parameter access and quantizer insertion.

    In init mode it *creates* parameters (numpy, seeded) and records
    param_specs / layer_specs. In apply modes it reads the flat parameter
    vector and threads qparams / LoRA / selection through each quantized
    layer in the same call order.
    """

    def __init__(self, cfg, mode, rng=None, flat=None, param_specs=None,
                 layer_specs=None, qparams=None, lora=None, sel=None):
        self.cfg = cfg
        self.mode = mode
        self.rng = rng
        self.flat = flat
        self.params = {}
        self.param_specs = param_specs or []
        self.layer_specs = layer_specs or []
        self.qparams = qparams
        self.lora = lora
        self.sel = sel
        self.qi = 0  # quant-layer cursor
        self.acts = []
        self.minmax = []
        self.act_samples = 512
        if flat is not None:
            for spec in self.param_specs:
                o, shape = spec["offset"], tuple(spec["shape"])
                size = int(np.prod(shape))
                self.params[spec["name"]] = flat[o:o + size].reshape(shape)

    # ---- parameter creation / access -------------------------------------
    def _make(self, name, shape, init):
        if self.mode == "init":
            fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
            if init == "he":
                v = self.rng.normal(size=shape) * math.sqrt(2.0 / max(fan_in, 1))
            elif init == "zero":
                v = np.zeros(shape)
            elif init == "one":
                v = np.ones(shape)
            elif init == "small":
                v = self.rng.normal(size=shape) * 0.02
            else:
                raise ValueError(init)
            arr = v.astype(np.float32)
            off = sum(int(np.prod(s["shape"])) for s in self.param_specs)
            self.param_specs.append({"name": name, "shape": list(shape), "offset": off})
            self.params[name] = arr
            return jnp.asarray(arr)
        return self.params[name]

    # ---- quantizer plumbing ----------------------------------------------
    def _qrow(self):
        return self.qparams[self.qi]

    def _lora_slices(self, spec):
        """A [H, r, K], B [H, N, r] slices of the flat LoRA vector."""
        cfg = self.cfg
        H, r = cfg.lora_hub, cfg.lora_rank
        K, N = spec["fan_in"], spec["fan_out"]
        o = spec["lora_offset"]
        a = self.lora[o:o + H * r * K].reshape(H, r, K)
        b = self.lora[o + H * r * K:o + H * r * K + H * N * r].reshape(H, N, r)
        return a, b

    def _act_quant(self, x):
        row = self._qrow()
        if self.mode == "qtrain":
            return ref.mixup_qdq_ste(x, row[3], row[4], row[5], row[6], row[7])
        return fp_quant.mixup_qdq_pallas(x, row[3], row[4], row[5], row[6], row[7])

    def _weight_quant(self, w):
        row = self._qrow()
        if self.mode == "qtrain":
            return ref.weight_qdq_ste(w, row[0], row[1], row[2])
        return fp_quant.signed_qdq_pallas(w, row[0], row[1], row[2])

    def _record_act(self, x):
        flat = x.reshape(-1)
        self.acts.append(jnp.resize(flat, (self.act_samples,)))
        self.minmax.append(jnp.stack([jnp.min(flat), jnp.max(flat)]))

    # ---- layers ------------------------------------------------------------
    def conv(self, name, x, cout, k=3, stride=1, zero_init=False, aal_hint=False):
        """Quantized 2D conv (NHWC, HWIO weights) with per-layer LoRA."""
        cfg = self.cfg
        cin = x.shape[-1]
        w = self._make(f"{name}.w", (k, k, cin, cout), "zero" if zero_init else "he")
        bias = self._make(f"{name}.b", (cout,), "zero")
        if self.mode == "init":
            self.layer_specs.append({
                "name": name, "kind": "conv", "fan_in": k * k * cin,
                "fan_out": cout, "k": k, "stride": stride, "aal": bool(aal_hint),
                "param": f"{name}.w",
            })
            self.qi += 1
        elif self.mode in ("fp", "calib"):
            if self.mode == "calib":
                self._record_act(x)
            self.qi += 1
        else:
            spec = self.layer_specs[self.qi]
            x = self._act_quant(x)
            wq = self._weight_quant(w)
            a, b = self._lora_slices(spec)
            s = self.sel[self.qi]  # [H] one-hot
            a_sel = jnp.einsum("h,hrk->rk", s, a)
            b_sel = jnp.einsum("h,hnr->nr", s, b)
            delta = (b_sel @ a_sel).reshape(cout, k, k, cin)
            delta = jnp.transpose(delta, (1, 2, 3, 0)) * (1.0 / cfg.lora_rank)
            w = wq + delta
            self.qi += 1
        if self.mode not in ("qtrain", "serve"):
            w = w if isinstance(w, jnp.ndarray) else jnp.asarray(w)
        y = jax.lax.conv_general_dilated(
            x, w, window_strides=(stride, stride), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return y + bias

    def linear(self, name, x, cout, aal_hint=False):
        """Quantized dense layer; serve mode uses the fused Pallas kernel."""
        cfg = self.cfg
        cin = x.shape[-1]
        w = self._make(f"{name}.w", (cin, cout), "he")
        bias = self._make(f"{name}.b", (cout,), "zero")
        if self.mode == "init":
            self.layer_specs.append({
                "name": name, "kind": "linear", "fan_in": cin, "fan_out": cout,
                "k": 1, "stride": 1, "aal": bool(aal_hint), "param": f"{name}.w",
            })
            self.qi += 1
            return x @ w + bias
        if self.mode in ("fp", "calib"):
            if self.mode == "calib":
                self._record_act(x)
            self.qi += 1
            return x @ w + bias
        spec = self.layer_specs[self.qi]
        row = self._qrow()
        x = self._act_quant(x)
        a, b = self._lora_slices(spec)
        s = self.sel[self.qi]
        a_sel = jnp.einsum("h,hrk->rk", s, a)
        b_sel = jnp.einsum("h,hnr->nr", s, b)
        self.qi += 1
        lead = x.shape[:-1]
        x2 = x.reshape(-1, cin).T  # [K, B*]
        if self.mode == "serve":
            y = lora_qmatmul.lora_qmatmul_pallas(
                w.T, x2, a_sel, b_sel, 1.0 / cfg.lora_rank, row[0], row[1], row[2])
        else:
            wq = ref.weight_qdq_ste(w, row[0], row[1], row[2])
            y = (wq.T + (b_sel @ a_sel) * (1.0 / cfg.lora_rank)) @ x2
        return y.T.reshape(*lead, cout) + bias

    def gn(self, name, x):
        scale = self._make(f"{name}.g", (x.shape[-1],), "one")
        bias = self._make(f"{name}.b", (x.shape[-1],), "zero")
        return group_norm(x, scale, bias, self.cfg.groups)


def silu(x):
    return x * jax.nn.sigmoid(x)


def _resblock(ctx, name, x, temb, cout):
    cin = x.shape[-1]
    h = ctx.gn(f"{name}.gn1", x)
    h = silu(h)
    h = ctx.conv(f"{name}.conv1", h, cout, aal_hint=True)
    tp = ctx.linear(f"{name}.temb", silu(temb), cout, aal_hint=True)
    h = h + tp[:, None, None, :]
    h = ctx.gn(f"{name}.gn2", h)
    h = silu(h)
    h = ctx.conv(f"{name}.conv2", h, cout, aal_hint=True)
    if cin != cout:
        x = ctx.conv(f"{name}.skip", x, cout, k=1)
    return x + h


def _attention(ctx, name, x):
    b, h, w, c = x.shape
    y = ctx.gn(f"{name}.gn", x)
    y = y.reshape(b, h * w, c)
    qkv = ctx.linear(f"{name}.qkv", y, 3 * c)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    att = jax.nn.softmax(q @ jnp.transpose(k, (0, 2, 1)) / math.sqrt(c), axis=-1)
    y = att @ v
    y = ctx.linear(f"{name}.proj", y, c)
    return x + y.reshape(b, h, w, c)


def unet(ctx, x, t, cond):
    """The denoiser eps_theta(x_t, t[, cond]) shared by every mode."""
    cfg = ctx.cfg
    c0 = cfg.base_ch
    c1 = cfg.base_ch * cfg.ch_mult[1]

    temb = sinusoidal_temb(t, cfg.temb_dim)
    temb = ctx.linear("temb.lin1", temb, cfg.temb_dim * 2, aal_hint=False)
    temb = ctx.linear("temb.lin2", silu(temb), cfg.temb_dim, aal_hint=True)
    if cfg.n_classes > 0:
        table = ctx._make("cls.emb", (cfg.n_classes, cfg.temb_dim), "small")
        onehot = jax.nn.one_hot(jnp.asarray(cond, jnp.int32), cfg.n_classes)
        temb = temb + onehot @ table

    h0 = ctx.conv("conv_in", x, c0)                    # [HW, c0] (8-bit layer)
    h1 = _resblock(ctx, "res1", h0, temb, c0)
    d1 = ctx.conv("down", silu(h1), c1, stride=2, aal_hint=True)
    h2 = _resblock(ctx, "res2", d1, temb, c1)
    m = _resblock(ctx, "mid", h2, temb, c1)
    m = _attention(ctx, "attn", m)
    u = jnp.concatenate([m, h2], axis=-1)
    u = _resblock(ctx, "res3", u, temb, c1)
    u = jnp.repeat(jnp.repeat(u, 2, axis=1), 2, axis=2)  # nearest upsample
    u = ctx.conv("up", silu(u), c0, aal_hint=True)
    u2 = jnp.concatenate([u, h1], axis=-1)
    u2 = _resblock(ctx, "res4", u2, temb, c0)
    out = ctx.gn("out.gn", u2)
    out = ctx.conv("conv_out", silu(out), cfg.in_ch, zero_init=True,
                   aal_hint=True)                       # (8-bit layer)
    return out


def init_model(cfg, seed=0):
    """Build params + specs by tracing the model once in init mode."""
    rng = np.random.default_rng(seed)
    ctx = Ctx(cfg, "init", rng=rng)
    x = jnp.zeros((1, cfg.img_hw, cfg.img_hw, cfg.in_ch), jnp.float32)
    t = jnp.zeros((1,), jnp.float32)
    cond = jnp.zeros((1,), jnp.float32)
    unet(ctx, x, t, cond)
    # assign LoRA offsets in layer order
    off = 0
    H, r = cfg.lora_hub, cfg.lora_rank
    for spec in ctx.layer_specs:
        spec["lora_offset"] = off
        off += H * r * spec["fan_in"] + H * spec["fan_out"] * r
    flat = np.concatenate([ctx.params[s["name"]].reshape(-1)
                           for s in ctx.param_specs])
    meta = {
        "param_specs": ctx.param_specs,
        "layer_specs": ctx.layer_specs,
        "n_params": int(flat.size),
        "n_layers": len(ctx.layer_specs),
        "lora_size": int(off),
        "router_size": cfg.temb_dim * len(ctx.layer_specs) * H
                       + len(ctx.layer_specs) * H,
    }
    return flat, meta


def apply_fp(cfg, meta, flat, x, t, cond):
    ctx = Ctx(cfg, "fp", flat=flat, param_specs=meta["param_specs"],
              layer_specs=meta["layer_specs"])
    return unet(ctx, x, t, cond)


def apply_calib(cfg, meta, flat, x, t, cond, samples=512):
    ctx = Ctx(cfg, "calib", flat=flat, param_specs=meta["param_specs"],
              layer_specs=meta["layer_specs"])
    ctx.act_samples = samples
    eps = unet(ctx, x, t, cond)
    return eps, jnp.stack(ctx.acts), jnp.stack(ctx.minmax)


def apply_quant(cfg, meta, flat, qparams, lora, sel, x, t, cond, mode="serve"):
    ctx = Ctx(cfg, mode, flat=flat, param_specs=meta["param_specs"],
              layer_specs=meta["layer_specs"], qparams=qparams, lora=lora,
              sel=sel)
    return unet(ctx, x, t, cond)
