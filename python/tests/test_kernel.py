"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes, formats, maxvals and zero points; the kernels and
the reference must agree to f32 ulp-level (the FMA-contraction of the final
`q*a + zp` can differ by 1 ulp between interpret and eager paths).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, fp_quant, lora_qmatmul

TOL = 5e-6


def _close(a, b, tol=TOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol, rtol=0)


# ---------------------------------------------------------------------------
# fixed-case agreement
# ---------------------------------------------------------------------------

FORMATS = [
    (1.0, 2, 1, 0.0), (1.0, 1, 2, 0.0), (1.0, 3, 0, 0.0), (1.0, 0, 3, 0.0),
    (0.0, 2, 2, -0.25), (0.0, 3, 1, -0.1), (0.0, 0, 4, -0.3),
    (1.0, -1, 4, 0.0), (0.0, -1, 4, -0.25),  # INT dispatch rows
    (1.0, -1, 8, 0.0), (0.0, -1, 8, -0.1),
]


@pytest.mark.parametrize("sign,e,m,zp", FORMATS)
def test_pallas_matches_ref(sign, e, m, zp):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(33, 17)).astype(np.float32) * 3)
    r = ref.mixup_qdq(x, sign, 2.7, e, m, zp)
    p = fp_quant.mixup_qdq_pallas(x, sign, 2.7, e, m, zp)
    _close(r, p)


def test_signed_wrapper_matches():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    _close(fp_quant.signed_qdq_pallas(x, 1.5, 2, 1),
           ref.fp_qdq_signed(x, 1.5, 2, 1))


def test_unsigned_wrapper_matches():
    rng = np.random.default_rng(2)
    x = jnp.asarray(np.abs(rng.normal(size=(64,)).astype(np.float32)) - 0.2)
    _close(fp_quant.unsigned_qdq_pallas(x, 1.5, 2, 2, -0.2),
           ref.fp_qdq_unsigned(x, 1.5, 2, 2, -0.2))


def test_lora_qmatmul_matches_ref():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(50, 24)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(24, 8)).astype(np.float32))
    a = jnp.asarray(rng.normal(size=(4, 24)).astype(np.float32) * 0.1)
    b = jnp.asarray(rng.normal(size=(50, 4)).astype(np.float32) * 0.1)
    r = ref.lora_qmatmul_ref(w, x, a, b, 0.5, 1.9, 2, 1)
    p = lora_qmatmul.lora_qmatmul_pallas(w, x, a, b, 0.5, 1.9, 2, 1)
    _close(r, p, tol=1e-4)  # matmul reassociation


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 700),
    sign=st.sampled_from([0.0, 1.0]),
    e=st.integers(0, 4),
    m=st.integers(1, 5),
    maxval=st.floats(0.05, 50.0),
    zp=st.floats(-0.3, 0.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sweep_shapes_formats(n, sign, e, m, maxval, zp, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=n) * maxval).astype(np.float32))
    r = ref.mixup_qdq(x, sign, maxval, e, m, zp)
    p = fp_quant.mixup_qdq_pallas(x, sign, maxval, e, m, zp)
    np.testing.assert_allclose(np.asarray(r), np.asarray(p),
                               atol=max(TOL, 1e-6 * maxval), rtol=0)


@settings(max_examples=25, deadline=None)
@given(
    e=st.integers(0, 3), m=st.integers(1, 4),
    maxval=st.floats(0.1, 10.0), seed=st.integers(0, 2**31 - 1),
)
def test_signed_qdq_invariants(e, m, maxval, seed):
    """Grid invariants: idempotence, bound, symmetry."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=256) * maxval).astype(np.float32))
    q = ref.fp_qdq_signed(x, maxval, e, m)
    q2 = ref.fp_qdq_signed(q, maxval, e, m)
    _close(q, q2, tol=1e-6 * max(1.0, maxval))          # idempotent
    assert float(jnp.max(jnp.abs(q))) <= maxval * (1 + 1e-6)  # bounded
    qn = ref.fp_qdq_signed(-x, maxval, e, m)
    _close(q, -qn, tol=1e-6 * max(1.0, maxval))         # odd symmetry


@settings(max_examples=25, deadline=None)
@given(
    e=st.integers(0, 3), m=st.integers(1, 4),
    maxval=st.floats(0.1, 10.0), zp=st.floats(-0.3, 0.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_unsigned_qdq_invariants(e, m, maxval, zp, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=256) * maxval).astype(np.float32))
    q = ref.fp_qdq_unsigned(x, maxval, e, m, zp)
    q2 = ref.fp_qdq_unsigned(q, maxval, e, m, zp)
    _close(q, q2, tol=1e-6 * max(1.0, maxval))
    assert float(jnp.min(q)) >= zp - 1e-6               # floor at zp
    assert float(jnp.max(q)) <= maxval + zp + 1e-5 * maxval


def test_quantization_error_bounded_by_halfstep():
    """In the top binade the error is <= step/2 = 2^-m * maxval/(2-2^-m)/2."""
    m = 2
    maxval = 1.0
    x = jnp.linspace(0.5, 1.0, 101).astype(jnp.float32) * maxval
    q = ref.fp_qdq_signed(x, maxval, 2, m)
    a = maxval / (2 - 2.0 ** -m)
    step_top = 2.0 ** -m * a
    assert float(jnp.max(jnp.abs(q - x))) <= step_top / 2 + 1e-7


def test_unsigned_beats_signed_on_silu_distribution():
    """The paper's Observation 1 at 4 bits: unsigned+zp wins on AAL data."""
    rng = np.random.default_rng(5)
    z = rng.normal(size=20000).astype(np.float32) * 2.0
    silu = z / (1.0 + np.exp(-z))  # SiLU output: asymmetric, min ~ -0.278
    x = jnp.asarray(silu)
    mx = float(np.max(silu))
    # best signed 4-bit (e+m = 3) vs best unsigned-with-zp 4-bit (e+m = 4)
    best_s = min(float(jnp.mean((ref.fp_qdq_signed(x, mx, e, 3 - e) - x) ** 2))
                 for e in range(4))
    best_u = min(float(jnp.mean(
        (ref.fp_qdq_unsigned(x, mx + 0.278, e, 4 - e, -0.278) - x) ** 2))
        for e in range(1, 5))
    assert best_u < best_s


def test_ste_gradient_is_identity():
    x = jnp.asarray(np.random.default_rng(6).normal(size=32).astype(np.float32))
    g = jax.grad(lambda t: jnp.sum(ref.mixup_qdq_ste(t, 1.0, 2.0, 2, 1, 0.0)))(x)
    _close(g, jnp.ones_like(x))


def test_int_dispatch_matches_int_ref():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=128).astype(np.float32))
    _close(ref.mixup_qdq(x, 1.0, 2.0, -1, 4, 0.0), ref.int_qdq_sym(x, 2.0, 4))
    _close(ref.mixup_qdq(x, 0.0, 2.0, -1, 4, -0.5),
           ref.int_qdq_asym(x, -0.5, 2.0, 4))
    _close(ref.weight_qdq(x, 2.0, -1, 4), ref.int_qdq_sym(x, 2.0, 4))
