"""L2 model tests: shapes, mode agreement, gradient flow, router STE."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import quantized as Q


@pytest.fixture(scope="module")
def ddim16():
    cfg = M.MODELS["ddim16"]
    flat, meta = M.init_model(cfg, seed=3)
    # break the zero-init of conv_out so quantization effects are visible
    rng = np.random.default_rng(4)
    flat = flat + rng.normal(size=flat.shape).astype(np.float32) * 0.02
    return cfg, jnp.asarray(flat), meta


def _inputs(cfg, b, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, cfg.img_hw, cfg.img_hw, cfg.in_ch))
                    .astype(np.float32))
    t = jnp.asarray(rng.integers(0, 100, size=b).astype(np.float32))
    cond = jnp.zeros((b,), jnp.float32)
    return x, t, cond


def _qparams(meta, wbits=4, abits=4):
    L = meta["n_layers"]
    qp = np.zeros((L, 8), np.float32)
    qp[:, 0] = 2.0; qp[:, 1] = 2; qp[:, 2] = wbits - 3
    qp[:, 3] = 1.0; qp[:, 4] = 6.0; qp[:, 5] = 2; qp[:, 6] = abits - 1
    qp[:, 7] = -0.2
    return jnp.asarray(qp)


def test_fp_forward_shape(ddim16):
    cfg, flat, meta = ddim16
    x, t, cond = _inputs(cfg, 2)
    eps = M.apply_fp(cfg, meta, flat, x, t, cond)
    assert eps.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(eps)))


def test_param_count_consistency(ddim16):
    cfg, flat, meta = ddim16
    assert flat.size == meta["n_params"]
    assert meta["n_params"] == sum(
        int(np.prod(s["shape"])) for s in meta["param_specs"])


def test_layer_specs_have_lora_offsets(ddim16):
    cfg, flat, meta = ddim16
    offs = [s["lora_offset"] for s in meta["layer_specs"]]
    assert offs == sorted(offs)
    H, r = cfg.lora_hub, cfg.lora_rank
    last = meta["layer_specs"][-1]
    end = last["lora_offset"] + H * r * last["fan_in"] + H * last["fan_out"] * r
    assert end == meta["lora_size"]


def test_qtrain_serve_agree(ddim16):
    """The STE reference path and the Pallas serving path must match."""
    cfg, flat, meta = ddim16
    x, t, cond = _inputs(cfg, 1)
    qp = _qparams(meta)
    lora = jnp.zeros((meta["lora_size"],))
    sel = jnp.tile(jnp.eye(cfg.lora_hub)[0], (meta["n_layers"], 1))
    a = M.apply_quant(cfg, meta, flat, qp, lora, sel, x, t, cond, mode="qtrain")
    b = M.apply_quant(cfg, meta, flat, qp, lora, sel, x, t, cond, mode="serve")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_quantization_changes_output(ddim16):
    cfg, flat, meta = ddim16
    x, t, cond = _inputs(cfg, 1)
    qp = _qparams(meta, 4, 4)
    lora = jnp.zeros((meta["lora_size"],))
    sel = jnp.tile(jnp.eye(cfg.lora_hub)[0], (meta["n_layers"], 1))
    eq = M.apply_quant(cfg, meta, flat, qp, lora, sel, x, t, cond, mode="qtrain")
    ef = M.apply_fp(cfg, meta, flat, x, t, cond)
    assert float(jnp.max(jnp.abs(eq - ef))) > 1e-5


def test_calib_outputs(ddim16):
    cfg, flat, meta = ddim16
    x, t, cond = _inputs(cfg, 2)
    eps, acts, mm = M.apply_calib(cfg, meta, flat, x, t, cond, samples=128)
    L = meta["n_layers"]
    assert acts.shape == (L, 128) and mm.shape == (L, 2)
    assert bool(jnp.all(mm[:, 0] <= mm[:, 1]))


def test_finetune_grads_flow(ddim16):
    cfg, flat, meta = ddim16
    x, t, cond = _inputs(cfg, 2)
    qp = _qparams(meta)
    rng = np.random.default_rng(9)
    lora = jnp.asarray(rng.normal(size=meta["lora_size"]).astype(np.float32)
                       * 0.01)
    router = jnp.asarray(rng.normal(size=meta["router_size"])
                         .astype(np.float32) * 0.1)
    hub = jnp.ones((cfg.lora_hub,))
    target = M.apply_fp(cfg, meta, flat, x,
                        jnp.full((2,), 37.0), cond)
    step = Q.make_finetune_step(cfg, meta)
    loss, gl, gr, sel = step(flat, qp, lora, router, hub, x, 37.0, 1.3,
                             target, cond)
    assert float(loss) > 0
    assert float(jnp.abs(gl).sum()) > 0, "LoRA grads must flow"
    assert float(jnp.abs(gr).sum()) > 0, "router grads must flow (STE)"
    # sel rows are one-hot
    assert np.allclose(np.asarray(sel).sum(-1), 1.0)
    assert np.allclose(np.sort(np.asarray(sel), -1)[:, :-1], 0.0)


def test_router_hub_mask(ddim16):
    cfg, flat, meta = ddim16
    rng = np.random.default_rng(10)
    router = jnp.asarray(rng.normal(size=meta["router_size"])
                         .astype(np.float32))
    mask = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    for t in (0.0, 13.0, 99.0):
        sel = Q.router_select(cfg, meta["n_layers"], router, t, mask)
        idx = np.argmax(np.asarray(sel), -1)
        assert (idx < 2).all(), "masked hub slots must never be selected"


def test_pretrain_step_decreases_loss(ddim16):
    cfg, flat, meta = ddim16
    rng = np.random.default_rng(11)
    b = 4
    x0 = jnp.asarray(rng.normal(size=(b, cfg.img_hw, cfg.img_hw, cfg.in_ch))
                     .astype(np.float32))
    noise = jnp.asarray(rng.normal(size=x0.shape).astype(np.float32))
    t = jnp.asarray([10.0, 30.0, 60.0, 90.0])
    abar = jnp.asarray([0.9, 0.6, 0.3, 0.1])
    cond = jnp.zeros((b,))
    step = jax.jit(Q.make_pretrain_step(cfg, meta))
    f = flat
    l0, g = step(f, x0, noise, t, abar, cond)
    f = f - 1e-3 * g  # plain SGD probe
    l1, _ = step(f, x0, noise, t, abar, cond)
    assert float(l1) < float(l0)


def test_conditional_model_uses_cond():
    cfg = M.MODELS["ldm8c"]
    flat, meta = M.init_model(cfg, seed=5)
    rng = np.random.default_rng(6)
    flat = jnp.asarray(flat + rng.normal(size=flat.shape).astype(np.float32)
                       * 0.02)
    x, t, _ = _inputs(cfg, 2, seed=7)
    e0 = M.apply_fp(cfg, meta, flat, x, t, jnp.asarray([0.0, 0.0]))
    e1 = M.apply_fp(cfg, meta, flat, x, t, jnp.asarray([3.0, 3.0]))
    assert float(jnp.max(jnp.abs(e0 - e1))) > 1e-6


def test_sinusoidal_temb_props():
    e = M.sinusoidal_temb(jnp.asarray([0.0, 5.0, 99.0]), 64)
    assert e.shape == (3, 64)
    assert np.allclose(np.asarray(e[0, :32]), 0.0)      # sin(0) = 0
    assert np.allclose(np.asarray(e[0, 32:]), 1.0)      # cos(0) = 1
