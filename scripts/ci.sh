#!/usr/bin/env bash
# CI gate for the crate: formatting, lints, then the tier-1 verify.
#
#   scripts/ci.sh
#
# Runs, in order:
#   cargo fmt --check                          formatting drift fails the gate
#   cargo clippy --all-targets -- -D warnings  lints over lib, tests, benches
#                                              and examples fail the gate
#   cargo build --release                      tier-1 verify, part 1
#   cargo test -q                              tier-1 verify, part 2
#
# Perf companion: scripts/bench.sh (perf_quant → BENCH_quant.json).
set -euo pipefail

root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$root/rust"

if [ ! -f Cargo.toml ]; then
    echo "error: rust/Cargo.toml not found — this checkout has no build" >&2
    echo "manifest (the crate manifest and vendored xla dep are provided" >&2
    echo "by the build environment). Run from a toolchain-equipped tree." >&2
    exit 1
fi

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy --all-targets -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== tier-1 verify =="
cargo build --release
cargo test -q

echo "ci.sh: all gates passed"
