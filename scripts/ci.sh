#!/usr/bin/env bash
# CI gate for the crate: formatting, lints, then the tier-1 verify.
#
#   scripts/ci.sh
#
# Runs, in order:
#   cargo fmt --check                          formatting drift fails the gate
#   cargo clippy --all-targets -- -D warnings  lints over lib, tests, benches
#                                              and examples fail the gate
#   cargo build --release                      tier-1 verify, part 1
#   cargo test -q                              tier-1 verify, part 2 — this
#                                              default tier includes the
#                                              recal sketch-persistence and
#                                              shadow-prober suites (unit,
#                                              props.rs, integration.rs)
#   overload smoke                             named re-run of the SLO
#                                              shed/downgrade and fault-plan
#                                              determinism integration tests
#   packed-backend smoke                       named re-run of the packed-
#                                              vs-graph serving parity test
#   chaos soak                                 named re-run of the storage-
#                                              fault kill-point soak and the
#                                              live-reconfigure determinism
#                                              test
#   trace-determinism smoke                    named re-run of the flight-
#                                              recorder logical-trace parity
#                                              test (1 vs 4 workers)
#   fleet-determinism smoke                    named re-run of the fleet
#                                              shard-count invariance test
#                                              (2 vs 4 shards: identical
#                                              merged windows, plans and
#                                              image bits) plus the bad-
#                                              window skip hardening test
#   test-count floor                           the summed `N passed` totals
#                                              must not drop below
#                                              scripts/test_floor.txt, so a
#                                              PR cannot silently delete or
#                                              stop compiling tests
#
# Perf companion: scripts/bench.sh (perf_quant → BENCH_quant.json).
set -euo pipefail

root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$root/rust"

if [ ! -f Cargo.toml ]; then
    echo "error: rust/Cargo.toml not found — this checkout has no build" >&2
    echo "manifest (the crate manifest and vendored xla dep are provided" >&2
    echo "by the build environment). Run from a toolchain-equipped tree." >&2
    exit 1
fi

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy --all-targets -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== tier-1 verify =="
cargo build --release
test_log="$(mktemp)"
cargo test -q 2>&1 | tee "$test_log"

echo "== overload smoke (SLO shed/downgrade + fault-recovery determinism) =="
# re-invoke the two robustness integration tests by name so an overload or
# fault-injection regression is called out on its own, not buried in the
# tier-1 wall of output (binaries are already built by the step above)
cargo test -q --test integration \
    overload_sheds_and_degrades_deterministically_across_workers \
    fault_plan_retries_are_deterministic_across_workers

echo "== packed-backend smoke (native fused path vs graph oracle) =="
# named re-run of the packed-vs-graph serving parity pin: the nibble-packed
# native backend drifting from the compiled fake-qdq oracle must fail CI on
# its own line (skips cleanly when artifacts are absent, like all
# integration tests)
cargo test -q --test integration \
    packed_backend_serving_matches_graph_oracle

echo "== chaos soak (storage-fault kill points + live reconfiguration) =="
# the crash-consistency story gets its own CI line: a server killed at any
# seeded checkpoint fault point must restart bit-identically, and a live
# SLO reconfigure must replay the same for any worker count
cargo test -q --test integration \
    chaos_checkpoint_kill_points_preserve_restart_decisions \
    reconfigure_and_ladder_rungs_are_deterministic_across_workers

echo "== trace-determinism smoke (flight-recorder logical trace, 1 vs 4 workers) =="
# the observability contract gets its own CI line: the logical event trace
# (wall-clock stripped) of an overload workload must be byte-identical for
# any worker count, and the shutdown postmortem must reload cleanly
cargo test -q --test integration \
    flight_recorder_trace_is_bit_identical_across_workers

echo "== fleet-determinism smoke (2 vs 4 shards: merged windows, plans, image bits) =="
# the fleet contract gets its own CI line: sharding the same traffic 2 or
# 4 ways must produce byte-identical canonically-merged windows, the same
# broadcast recalibration plan and bit-identical images — and a shard
# handing back a malformed window must be skipped, never fatal
cargo test -q --test integration \
    fleet_serving_is_shard_count_invariant_and_merges_drift \
    fleet_aggregation_skips_bad_shard_windows_instead_of_dying

echo "== test-count regression guard =="
total=$(grep -E 'test result: ok' "$test_log" \
    | sed -E 's/.*ok\. ([0-9]+) passed.*/\1/' \
    | awk '{s+=$1} END {print s+0}')
rm -f "$test_log"
floor=$(cat "$root/scripts/test_floor.txt")
echo "tests passed: $total (checked-in floor: $floor)"
if [ "$total" -lt "$floor" ]; then
    echo "error: test count regressed below the floor ($total < $floor)." >&2
    echo "If tests were intentionally removed or consolidated, lower" >&2
    echo "scripts/test_floor.txt in the same PR and say why." >&2
    exit 1
fi

echo "ci.sh: all gates passed"
