#!/usr/bin/env bash
# Perf gate for the quantization and serving hot paths.
#
# Builds --release, runs the perf_quant and perf_serving bench targets,
# and leaves machine-readable BENCH_quant.json / BENCH_serving.json at
# the repo root so the perf trajectory is comparable across PRs:
#   * BENCH_quant.json — grid-segment engine vs the retained *_scalar
#     oracle, the msfp_table5_sweep_cold vs msfp_table5_sweep_session
#     QuantSession amortization pair, and the recal_one_layer vs
#     rebuild_full_session online-recalibration pair (incremental
#     update_layer_calib rebuild vs cold session rebuild, 12 layers);
#   * BENCH_serving.json — per-eval latency by batch class, the
#     coordinator_sequential_exec vs coordinator_parallel round-executor
#     throughput pair, the selection-cache hit rate, the hot_swap_stall
#     row (mean round latency with a background recalibration swap
#     landing vs without), the probe_overhead row (shadow prober at
#     budget 2 vs 0), and the restart_{cold,warm}_rounds_to_swap pair
#     (drift detection from an empty vs a restored sketch window).
#
#   scripts/bench.sh
#
# Env:
#   BENCH_JSON           quant output path  (default: <repo>/BENCH_quant.json)
#   BENCH_SERVING_JSON   serving output path (default: <repo>/BENCH_serving.json)
#
# Tier-1 verify stays `cargo build --release && cargo test -q` (run in
# rust/); this script is the perf companion, not a replacement.
set -euo pipefail

root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$root/rust"
export BENCH_JSON="${BENCH_JSON:-$root/BENCH_quant.json}"
export BENCH_SERVING_JSON="${BENCH_SERVING_JSON:-$root/BENCH_serving.json}"

if [ ! -f Cargo.toml ]; then
    echo "error: rust/Cargo.toml not found — this checkout has no build" >&2
    echo "manifest (the crate manifest and vendored xla dep are provided" >&2
    echo "by the build environment). Run from a toolchain-equipped tree." >&2
    exit 1
fi

cargo build --release
cargo bench --bench perf_quant
cargo bench --bench perf_serving

echo "bench results: $BENCH_JSON"
echo "               $BENCH_SERVING_JSON"
