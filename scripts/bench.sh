#!/usr/bin/env bash
# Perf gate for the quantization hot paths.
#
# Builds --release, runs the perf_quant bench target, and leaves a
# machine-readable BENCH_quant.json at the repo root so the perf
# trajectory (grid-segment engine vs the retained *_scalar oracle, and
# the msfp_table5_sweep_cold vs msfp_table5_sweep_session QuantSession
# amortization pair) is comparable across PRs.
#
#   scripts/bench.sh
#
# Env:
#   BENCH_JSON   output path (default: <repo>/BENCH_quant.json)
#
# Tier-1 verify stays `cargo build --release && cargo test -q` (run in
# rust/); this script is the perf companion, not a replacement.
set -euo pipefail

root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$root/rust"
export BENCH_JSON="${BENCH_JSON:-$root/BENCH_quant.json}"

if [ ! -f Cargo.toml ]; then
    echo "error: rust/Cargo.toml not found — this checkout has no build" >&2
    echo "manifest (the crate manifest and vendored xla dep are provided" >&2
    echo "by the build environment). Run from a toolchain-equipped tree." >&2
    exit 1
fi

cargo build --release
cargo bench --bench perf_quant

echo "bench results: $BENCH_JSON"
