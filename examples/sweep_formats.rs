//! Format-space exploration: why mixup-sign wins on SiLU activations.
//!
//! Sweeps every ExMy format (signed, and unsigned with/without zero point)
//! over synthetic NAL (gaussian) and AAL (SiLU) activation distributions at
//! 4/6/8 bits — a self-contained reproduction of the paper's Observations
//! 1 + Figure 2/4 mechanics, no artifacts required.
//!
//!   cargo run --release --example sweep_formats

use msfp::quant::format::{act_signed_formats, act_unsigned_formats, zp_space, SILU_MIN};
use msfp::quant::search::{linspace, search_signed, search_unsigned};
use msfp::util::rng::Rng;

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn main() {
    let mut rng = Rng::new(7);
    let nal: Vec<f32> = (0..20_000).map(|_| rng.normal() * 1.5).collect();
    let aal: Vec<f32> = (0..20_000).map(|_| silu(rng.normal() * 2.5)).collect();

    println!("SiLU trough minimum: {SILU_MIN} (the zero-point search space target)\n");
    println!("{:<6} {:<10} {:>14} {:>14} {:>10}", "bits", "data", "best signed", "best uns+zp", "ratio");
    for bits in [4, 6, 8] {
        for (name, xs) in [("NAL", &nal), ("AAL", &aal)] {
            let maxval0 = xs.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
            let maxvals = linspace(maxval0 / 60.0, maxval0, 60);
            let s = search_signed(xs, &act_signed_formats(bits), &maxvals)
                .expect("signed search space is non-empty");
            let u = search_unsigned(xs, &act_unsigned_formats(bits), &maxvals, &zp_space())
                .expect("unsigned search space is non-empty");
            let (sq, uq) = (s.quantizer, u.quantizer);
            println!(
                "{:<6} {:<10} {:>10.3e} {:>3} {:>10.3e} {:>3} {:>9.2}x",
                bits,
                name,
                s.mse,
                format_of(&sq),
                u.mse,
                format_of(&uq),
                s.mse / u.mse.max(1e-18)
            );
        }
    }
    println!("\nReading: on AALs at 4 bits the unsigned+zp grid should win by a large factor");
    println!("(the paper's Observation 1); on NALs signed stays competitive, so MSFP mixes.");
}

fn format_of(q: &msfp::quant::search::Quantizer) -> String {
    match q {
        msfp::quant::search::Quantizer::SignedFp { fmt, .. } => fmt.to_string(),
        msfp::quant::search::Quantizer::UnsignedFp { fmt, .. } => fmt.to_string(),
        _ => "INT".into(),
    }
}
