//! Serving scenario: a mixed workload of generation requests (different
//! sizes, step counts and samplers) against the 4-bit quantized model,
//! demonstrating step-level continuous batching and reporting
//! latency/throughput — the edge-deployment story of the paper's intro.
//!
//!   make artifacts && cargo run --release --example serve_quantized

use std::sync::Arc;

use anyhow::Result;
use msfp::config::{MethodSpec, Scale};
use msfp::coordinator::{self, Request, ServeMode, ServerCfg};
use msfp::data::Corpus;
use msfp::eval::generate::SamplerKind;
use msfp::pipeline::Pipeline;
use msfp::runtime::Denoiser;
use msfp::util::rng::Rng;

fn main() -> Result<()> {
    let pl = Pipeline::new(&Pipeline::default_artifacts_dir(), Scale::from_env())?;
    let p = pl.prepare(Corpus::CifarSyn)?;

    // quantize to W4A4 (PTQ-only here: serving setup time matters)
    let calib = pl.calibrate(&p)?;
    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;
    let q = pl.quantize(&p, &spec, &calib)?;

    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &p.info)?);
    let handle = coordinator::spawn(
        den,
        p.info.clone(),
        pl.sched.clone(),
        Arc::new(p.params.clone()),
        ServerCfg { mode: ServeMode::Quant(q.state), decode_latents: false, seed: 4, workers: 0 },
    );

    // mixed workload: bursts of small interactive requests + large batch
    // jobs + a couple of fast-sampler requests
    let mut rng = Rng::new(2024);
    let mut rxs = Vec::new();
    for i in 0..16 {
        let mut req = Request::new(0, 1 + rng.below(4), pl.scale.steps);
        req.seed = i;
        if i % 5 == 4 {
            req.sampler = SamplerKind::Plms;
        }
        rxs.push(handle.submit(req)?);
    }
    rxs.push(handle.submit(Request::new(0, 12, pl.scale.steps))?); // batch job

    for rx in rxs {
        let r = rx.recv()?;
        println!(
            "request {:2}: {:2} images, {:3} evals, {:7.1} ms",
            r.id,
            r.n,
            r.evals,
            r.latency.as_secs_f64() * 1e3
        );
    }
    let m = handle.shutdown();
    println!("\nserving summary: {}", m.report());
    println!(
        "continuous batching lifted mean batch to {:.1} ({}% slot fill)",
        m.mean_batch(),
        (m.mean_fill() * 100.0) as u32
    );
    Ok(())
}
