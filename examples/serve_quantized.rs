//! Serving scenario: a mixed workload of generation requests (different
//! sizes, step counts and samplers) against the 4-bit quantized model,
//! demonstrating step-level continuous batching, plus the *self-
//! calibrating* recalibration loop:
//!
//!  * an externally simulated drifted stream on layer 0 (the monitoring-
//!    sidecar producer) rides the shared sketch handle;
//!  * the in-process shadow prober (`ServerCfg::probe_budget`) recycles a
//!    budgeted slice of each round's request latents through the
//!    calibration graph, so the server also observes its own traffic;
//!  * drift checks hot-swap re-searched qparams between rounds, and the
//!    drift window persists to a state dir (`ServeRecal::state_dir`) —
//!    re-run this example and the server resumes the saved window instead
//!    of starting blind;
//!  * the workload carries SLO classes against a queue budget: under the
//!    resulting overload, interactive requests are downgraded (step cuts
//!    at admission, plus a pre-built W3A3→W2A3 degradation ladder whose
//!    rung tracks backlog depth) while an impossible-deadline best-effort
//!    request is explicitly shed.
//!
//!   make artifacts && cargo run --release --example serve_quantized

use std::sync::{Arc, Mutex};

use anyhow::Result;
use msfp::config::{MethodSpec, Scale};
use msfp::coordinator::{
    self, degradation_ladder, Request, Response, ServeMode, ServeRecal, ServerCfg, SloCfg, SloClass,
};
use msfp::data::Corpus;
use msfp::eval::generate::SamplerKind;
use msfp::pipeline::Pipeline;
use msfp::quant::msfp::{Method, QuantOpts};
use msfp::recal::SketchSet;
use msfp::runtime::Denoiser;
use msfp::util::rng::Rng;

fn main() -> Result<()> {
    let pl = Pipeline::new(&Pipeline::default_artifacts_dir(), Scale::from_env())?;
    let p = pl.prepare(Corpus::CifarSyn)?;

    // quantize to W4A4 (PTQ-only here: serving setup time matters), keeping
    // the search session alive — it is the recalibration baseline
    let session = pl.build_session(&p)?;
    let mut spec = MethodSpec::ours(4, 2, 0);
    spec.finetune = None;
    let q = pl.quantize_with_session(&p, &session, &spec)?;

    // online recalibration: producers feed per-layer activation sketches
    // through this handle; here we simulate drift on layer 0 by replaying
    // its calibration stream shifted and rescaled
    let info = &p.info;
    let opts = QuantOpts::new(Method::Msfp, info.n_layers, 4, 4)
        .with_io_8bit(&info.io_layer_indices());
    let sketches = Arc::new(Mutex::new(SketchSet::new(
        info.n_layers,
        4,
        256,
        pl.sched.t_total,
        7,
    )));
    {
        let mut set = sketches.lock().unwrap();
        let mut rng = Rng::new(8);
        for (l, c) in session.calib().iter().enumerate() {
            let (scale, shift) = if l == 0 { (1.6, 0.4) } else { (1.0, 0.0) };
            for chunk in c.acts.chunks(128) {
                let t = rng.range(0.0, pl.sched.t_total as f32);
                let vals: Vec<f32> = chunk.iter().map(|v| v * scale + shift).collect();
                set.observe(l, t, &vals);
            }
            // exact extrema: the subsampled acts miss the full-tensor
            // min/max the baseline carries
            set.widen_layer(l, 0.0, c.min * scale + shift, c.max * scale + shift);
        }
    }
    // pre-build the overload degradation ladder before the session moves
    // into the recal config: the same search at W3A3 and W2A3 on non-IO
    // layers — nearly free, since memoized layers whose bits didn't drop
    // replay. Deeper backlogs select deeper (coarser) rungs.
    let ladder = degradation_ladder(&session, &opts, &q.state, &[(3, 3), (2, 3)]);

    let mut recal = ServeRecal::new(session, opts, Arc::clone(&sketches));
    recal.every_rounds = 4;
    // persistence: the drift window (and each hot-swap's quant state) is
    // checkpointed here and restored on the next run of this example
    let state_dir = pl.serving_state_dir("example");
    println!("serving state dir: {}", state_dir.root().display());
    let recal = recal.with_state_dir(state_dir);

    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &p.info)?);
    let handle = coordinator::spawn(
        den,
        p.info.clone(),
        pl.sched.clone(),
        Arc::new(p.params.clone()),
        ServerCfg {
            seed: 4,
            recal: Some(recal),
            // self-calibration: up to 2 recycled-latent calib probes per
            // round feed the same sketches the simulated stream does
            probe_budget: 2,
            // overload policy: admission budget of 8 samples per round;
            // over-budget interactive requests lose 2 steps at admission
            // and ride the ladder rung matching the round's backlog depth
            slo: SloCfg { queue_budget: 8, step_cut: 2, ladder },
            ..ServerCfg::new(ServeMode::Quant(q.state))
        },
    );

    // mixed workload: bursts of small interactive requests + large batch
    // jobs + a couple of fast-sampler requests, spread over SLO classes
    let mut rng = Rng::new(2024);
    let mut rxs = Vec::new();
    for i in 0..16 {
        let mut req = Request::new(0, 1 + rng.below(4), pl.scale.steps).with_slo(
            match i % 3 {
                0 => SloClass::Interactive,
                1 => SloClass::Batch,
                _ => SloClass::BestEffort,
            },
        );
        req.seed = i;
        if i % 5 == 4 {
            req.sampler = SamplerKind::Plms;
        }
        rxs.push(handle.submit(req)?);
    }
    rxs.push(handle.submit(Request::new(0, 12, pl.scale.steps))?); // batch job
    // an opportunistic request with a deadline it cannot meet under this
    // load: the scheduler sheds it explicitly instead of letting it hang
    let mut doomed = Request::new(0, 6, pl.scale.steps).with_slo(SloClass::BestEffort);
    doomed.deadline_rounds = 2;
    rxs.push(handle.submit(doomed)?);

    for rx in rxs {
        match rx.recv()? {
            Response::Done(r) => println!(
                "request {:2}: {:2} images, {:3} evals, {:7.1} ms{}",
                r.id,
                r.n,
                r.evals,
                r.latency.as_secs_f64() * 1e3,
                if r.degraded { "  (degraded)" } else { "" }
            ),
            Response::Shed { id, class, reason } => {
                println!("request {id:2}: shed ({class:?}: {reason})")
            }
        }
    }
    let m = handle.shutdown();
    println!("\nserving summary: {}", m.report());
    println!(
        "continuous batching lifted mean batch to {:.1} ({}% slot fill)",
        m.mean_batch(),
        (m.mean_fill() * 100.0) as u32
    );
    println!(
        "online recalibration: {} drift check(s), {} hot-swap(s) covering {} layer(s)",
        m.recal_checks, m.recal_swaps, m.recal_layers
    );
    println!(
        "shadow prober: {} probe(s) fed, {} skipped by the budget gate, {} failed",
        m.probes, m.probes_skipped, m.probes_failed
    );
    println!(
        "overload: {} shed, {} downgraded round(s) (per-rung {:?}), {} step cut(s); interactive queue wait p50/p99 = {}/{} rounds",
        m.shed_total(),
        m.downgraded_rounds,
        m.rung_rounds,
        m.downgraded_steps,
        m.queue_wait_p(SloClass::Interactive, 0.5),
        m.queue_wait_p(SloClass::Interactive, 0.99)
    );
    Ok(())
}
