//! Quickstart: quantize a pretrained diffusion model to 4-bit FP with MSFP
//! + TALoRA + DFA and compare against full precision.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Uses the fast scale preset (MSFP_SCALE=full for paper-protocol budgets).

use anyhow::Result;
use msfp::config::{MethodSpec, Scale};
use msfp::data::Corpus;
use msfp::eval::generate::SamplerKind;
use msfp::pipeline::Pipeline;

fn main() -> Result<()> {
    let pl = Pipeline::new(&Pipeline::default_artifacts_dir(), Scale::from_env())?;

    // 1. a pretrained FP diffusion model (trained & cached by the repo)
    let prepared = pl.prepare(Corpus::CelebaSyn)?;
    println!(
        "pretrained celeba-syn: loss {:.4} -> {:.4}",
        prepared.pretrain_losses.first().unwrap(),
        prepared.pretrain_losses.last().unwrap()
    );

    // 2. full-precision reference
    let (fp, _) = pl.evaluate_spec(&prepared, &MethodSpec::fp(), SamplerKind::Ddim, 0.0, 1)?;
    println!("FP 32/32      : {}", fp.row());

    // 3. ours: MSFP + TALoRA(h=2) + DFA at W4A4
    let spec = MethodSpec::ours(4, 2, pl.scale.ft_epochs);
    let (ours, q) = pl.evaluate_spec(&prepared, &spec, SamplerKind::Ddim, 0.0, 1)?;
    let q = q.unwrap();
    println!("Ours  4/4     : {}", ours.row());
    println!(
        "mixup: {} AALs detected, unsigned FP chosen on {:.0}% of them",
        q.scheme.n_aal(),
        q.scheme.unsigned_fraction_on_aals() * 100.0
    );
    println!(
        "degradation vs FP: ΔFID-syn = {:+.2} (paper's W4A4 gap on CelebA: +1.2)",
        ours.fid - fp.fid
    );
    Ok(())
}
