//! End-to-end driver (DESIGN.md §validation): exercises every layer of the
//! system on a real small workload and reports the paper's headline
//! comparison. Results of a run of this binary are recorded in
//! EXPERIMENTS.md.
//!
//! Stages: pretrain (loss curve logged) → calibrate → MSFP search →
//! TALoRA+DFA fine-tune → batched sampling → FID-syn/IS-syn eval →
//! serving throughput, for FP vs INT-PTQ-FT baseline vs ours at W4A4.
//!
//!   make artifacts && cargo run --release --example end_to_end

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use msfp::config::{MethodSpec, Scale};
use msfp::coordinator::{self, Request, ServeMode, ServerCfg};
use msfp::data::Corpus;
use msfp::eval::generate::SamplerKind;
use msfp::pipeline::Pipeline;
use msfp::runtime::Denoiser;

fn main() -> Result<()> {
    let t0 = Instant::now();
    let pl = Pipeline::new(&Pipeline::default_artifacts_dir(), Scale::from_env())?;
    println!(
        "== end-to-end: celeba-syn, scale: pretrain {} steps / {} DDIM steps / {} eval imgs ==",
        pl.scale.pretrain_steps, pl.scale.steps, pl.scale.eval_n
    );

    // --- stage 1: pretrain ------------------------------------------------
    let p = pl.prepare(Corpus::CelebaSyn)?;
    let l = &p.pretrain_losses;
    println!("\n[1] pretrain loss curve (every 10%):");
    for i in (0..l.len()).step_by((l.len() / 10).max(1)) {
        println!("    step {i:4}: {:.4}", l[i]);
    }
    println!("    final    : {:.4}", l.last().unwrap());

    // --- stage 2+3: calibrate + quantize (three methods) -------------------
    let e = pl.scale.ft_epochs;
    let specs = [
        MethodSpec::fp(),
        MethodSpec::qdiffusion_like(4),
        MethodSpec::efficientdm_like(4, e),
        MethodSpec::ours(4, 2, e),
    ];
    println!("\n[2] quantize + fine-tune + evaluate (W4A4):");
    let mut results = Vec::new();
    for spec in &specs {
        let (r, q) = pl.evaluate_spec(&p, spec, SamplerKind::Ddim, 0.0, 42)?;
        if let Some(q) = &q {
            if let Some(ft) = &q.ft_stats {
                println!(
                    "    {}: finetune loss {:.4} -> {:.4}",
                    spec.label,
                    ft.losses.first().unwrap(),
                    ft.losses.last().unwrap()
                );
            }
        }
        println!("    {:<22} {}", spec.label, r.row());
        results.push((spec.label.clone(), r, q));
    }

    // headline check: ours beats the INT fine-tuning baseline at 4 bits
    let fid = |label: &str| {
        results.iter().find(|(l, ..)| l == label).map(|(_, r, _)| r.fid).unwrap()
    };
    println!("\n[3] headline: Ours(h=2) FID {:.2} vs EfficientDM-like {:.2} vs PTQ-only {:.2} (FP {:.2})",
        fid("Ours (h=2)"), fid("EfficientDM-like"), fid("Q-Diffusion-like"), fid("FP"));

    // --- stage 4: serve the quantized model -------------------------------
    let ours = results.pop().unwrap().2.unwrap();
    let den = Arc::new(Denoiser::new(Arc::clone(&pl.engine), &p.info)?);
    let handle = coordinator::spawn(
        den,
        p.info.clone(),
        pl.sched.clone(),
        Arc::new(p.params.clone()),
        ServerCfg { seed: 9, ..ServerCfg::new(ServeMode::Quant(ours.state)) },
    );
    let t_serve = Instant::now();
    let rxs = handle.submit_many(
        (0..8)
            .map(|i| {
                let mut r = Request::new(0, 2, pl.scale.steps);
                r.seed = i;
                r
            })
            .collect(),
    )?;
    for rx in rxs {
        rx.recv()?;
    }
    let m = handle.shutdown();
    println!("\n[4] quantized serving ({} concurrent requests): {}", 8, m.report());
    println!("    serve wall {:.1}s", t_serve.elapsed().as_secs_f64());

    println!("\ntotal wall time {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
